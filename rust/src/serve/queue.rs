//! Admission control: a bounded queue in front of the coalescer.
//!
//! The queue is the backpressure boundary of the serving layer. Depth is
//! bounded at construction, so a traffic spike turns into explicit
//! [`AdmissionError::Overloaded`] rejections (or a stalled submitter, if
//! the caller prefers [`AdmissionQueue::submit`]'s blocking semantics) —
//! never into unbounded buffering. Shutdown is a marker in the queue:
//! everything admitted ahead of it is still served, anything behind it
//! is answered with an explicit shutdown error by the coalescer's drain
//! pass, so no responder is ever dropped silently.

use super::{ForwardRequest, ForwardResponse, LinearRequest, LinearResponse};
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Why a submission was not admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionError {
    /// The queue is at capacity. Explicit backpressure: the caller decides
    /// whether to retry, shed, or fall back — the server never buffers
    /// unboundedly.
    Overloaded,
    /// The server is shutting down (or already gone); no new work is
    /// admitted.
    ShuttingDown,
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::Overloaded => write!(f, "server overloaded (admission queue full)"),
            AdmissionError::ShuttingDown => write!(f, "server shutting down"),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Channel a response is delivered on.
pub(crate) type Responder = mpsc::Sender<Result<LinearResponse, String>>;

/// One admitted request, on its way to the coalescer.
pub(crate) struct ServeJob {
    /// Registry key of the target model.
    pub model: String,
    pub req: LinearRequest,
    /// Admission time — the coalescer records queue-to-response latency
    /// from this.
    pub enqueued: Instant,
    pub tx: Responder,
}

/// Channel a forward response is delivered on.
pub(crate) type ForwardResponder = mpsc::Sender<Result<ForwardResponse, String>>;

/// One admitted whole-model request (PR 7), on its way to the
/// coalescer's continuous-batching scheduler.
pub(crate) struct ForwardJob {
    /// Registry key of the target forward.
    pub model: String,
    pub req: ForwardRequest,
    pub enqueued: Instant,
    pub tx: ForwardResponder,
}

pub(crate) enum Job {
    Linear(ServeJob),
    Forward(ForwardJob),
    Shutdown,
}

/// Producer side of the bounded admission queue.
pub struct AdmissionQueue {
    tx: mpsc::SyncSender<Job>,
    depth: Arc<AtomicUsize>,
    shutting_down: Arc<AtomicBool>,
    capacity: usize,
}

/// Consumer side, handed to [`super::Coalescer::run`].
pub struct JobReceiver {
    rx: mpsc::Receiver<Job>,
    depth: Arc<AtomicUsize>,
}

impl AdmissionQueue {
    /// Build a queue admitting at most `capacity` waiting requests
    /// (clamped to ≥ 1). Returns the producer handle and the receiver the
    /// coalescer drives.
    pub fn bounded(capacity: usize) -> (AdmissionQueue, JobReceiver) {
        let capacity = capacity.max(1);
        let (tx, rx) = mpsc::sync_channel(capacity);
        let depth = Arc::new(AtomicUsize::new(0));
        let queue = AdmissionQueue {
            tx,
            depth: depth.clone(),
            shutting_down: Arc::new(AtomicBool::new(false)),
            capacity,
        };
        (queue, JobReceiver { rx, depth })
    }

    /// The depth bound this queue was built with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Requests admitted but not yet picked up by the coalescer.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Whether [`AdmissionQueue::begin_shutdown`] has been called.
    pub fn is_shutting_down(&self) -> bool {
        self.shutting_down.load(Ordering::SeqCst)
    }

    /// Non-blocking admission: [`AdmissionError::Overloaded`] when the
    /// queue is full. On success returns the receiver the response
    /// arrives on.
    pub fn try_submit(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, String>>, AdmissionError> {
        if self.is_shutting_down() {
            return Err(AdmissionError::ShuttingDown);
        }
        let (job, rrx) = make_job(model, req);
        // Reserve the depth slot *before* the send: once the job is in
        // the channel a fast consumer may decrement immediately, and a
        // post-send increment could wrap depth below zero transiently.
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(Job::Linear(job)) {
            Ok(()) => Ok(rrx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                Err(AdmissionError::Overloaded)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                Err(AdmissionError::ShuttingDown)
            }
        }
    }

    /// Blocking admission: waits for queue space instead of rejecting —
    /// backpressure becomes "the submitter stalls", matching
    /// `EvalService::submit_linear`'s historical contract.
    pub fn submit(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, String>>, AdmissionError> {
        if self.is_shutting_down() {
            return Err(AdmissionError::ShuttingDown);
        }
        let (job, rrx) = make_job(model, req);
        // Same reserve-then-send ordering as `try_submit`.
        self.depth.fetch_add(1, Ordering::Relaxed);
        if self.tx.send(Job::Linear(job)).is_err() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            return Err(AdmissionError::ShuttingDown);
        }
        Ok(rrx)
    }

    /// Non-blocking admission of a whole-model forward request. Same
    /// backpressure contract as [`AdmissionQueue::try_submit`]: a forward
    /// occupies one queue slot regardless of its token count — token-level
    /// bounds are the scheduler's job ([`super::BatchConfig`]).
    pub fn try_submit_forward(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> Result<mpsc::Receiver<Result<ForwardResponse, String>>, AdmissionError> {
        if self.is_shutting_down() {
            return Err(AdmissionError::ShuttingDown);
        }
        let (job, rrx) = make_forward_job(model, req);
        // Reserve-then-send, exactly as `try_submit`.
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(Job::Forward(job)) {
            Ok(()) => Ok(rrx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                Err(AdmissionError::Overloaded)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                Err(AdmissionError::ShuttingDown)
            }
        }
    }

    /// Blocking admission of a whole-model forward request.
    pub fn submit_forward(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> Result<mpsc::Receiver<Result<ForwardResponse, String>>, AdmissionError> {
        if self.is_shutting_down() {
            return Err(AdmissionError::ShuttingDown);
        }
        let (job, rrx) = make_forward_job(model, req);
        self.depth.fetch_add(1, Ordering::Relaxed);
        if self.tx.send(Job::Forward(job)).is_err() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            return Err(AdmissionError::ShuttingDown);
        }
        Ok(rrx)
    }

    /// Stop admitting and wake the coalescer with a shutdown marker. The
    /// coalescer serves everything admitted before the marker, then
    /// answers anything behind it with an explicit shutdown error.
    pub fn begin_shutdown(&self) {
        if !self.shutting_down.swap(true, Ordering::SeqCst) {
            let _ = self.tx.send(Job::Shutdown);
        }
    }

    /// Test hook: enqueue past the shutdown flag, to exercise the drain
    /// path deterministically (a job *behind* the marker).
    #[cfg(test)]
    pub(crate) fn submit_behind_shutdown(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> mpsc::Receiver<Result<LinearResponse, String>> {
        let (job, rrx) = make_job(model, req);
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx.send(Job::Linear(job)).expect("queue gone");
        rrx
    }

    /// Test hook: enqueue a forward past the shutdown flag (the drain
    /// path must answer it, never drop its responder).
    #[cfg(test)]
    pub(crate) fn submit_forward_behind_shutdown(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> mpsc::Receiver<Result<ForwardResponse, String>> {
        let (job, rrx) = make_forward_job(model, req);
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx.send(Job::Forward(job)).expect("queue gone");
        rrx
    }
}

fn make_job(
    model: &str,
    req: LinearRequest,
) -> (ServeJob, mpsc::Receiver<Result<LinearResponse, String>>) {
    let (rtx, rrx) = mpsc::channel();
    let job =
        ServeJob { model: model.to_string(), req, enqueued: Instant::now(), tx: rtx };
    (job, rrx)
}

fn make_forward_job(
    model: &str,
    req: ForwardRequest,
) -> (ForwardJob, mpsc::Receiver<Result<ForwardResponse, String>>) {
    let (rtx, rrx) = mpsc::channel();
    let job =
        ForwardJob { model: model.to_string(), req, enqueued: Instant::now(), tx: rtx };
    (job, rrx)
}

impl JobReceiver {
    fn note(&self, job: &Job) {
        if matches!(job, Job::Linear(_) | Job::Forward(_)) {
            self.depth.fetch_sub(1, Ordering::Relaxed);
        }
    }

    pub(crate) fn recv(&self) -> Result<Job, mpsc::RecvError> {
        let job = self.rx.recv()?;
        self.note(&job);
        Ok(job)
    }

    pub(crate) fn recv_timeout(&self, timeout: Duration) -> Result<Job, mpsc::RecvTimeoutError> {
        let job = self.rx.recv_timeout(timeout)?;
        self.note(&job);
        Ok(job)
    }

    pub(crate) fn try_recv(&self) -> Result<Job, mpsc::TryRecvError> {
        let job = self.rx.try_recv()?;
        self.note(&job);
        Ok(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn req() -> LinearRequest {
        LinearRequest { name: "w".into(), x: Tensor::zeros(&[1, 4]) }
    }

    /// With no consumer attached, admission beyond capacity is an
    /// explicit `Overloaded` — fully deterministic backpressure.
    #[test]
    fn overload_is_explicit_at_capacity() {
        let (q, _rx) = AdmissionQueue::bounded(2);
        assert_eq!(q.capacity(), 2);
        let _r1 = q.try_submit("m", req()).unwrap();
        let _r2 = q.try_submit("m", req()).unwrap();
        assert_eq!(q.depth(), 2);
        assert_eq!(q.try_submit("m", req()).unwrap_err(), AdmissionError::Overloaded);
        // Still overloaded, still explicit — nothing was buffered.
        assert_eq!(q.try_submit("m", req()).unwrap_err(), AdmissionError::Overloaded);
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn shutdown_rejects_new_admissions() {
        let (q, rx) = AdmissionQueue::bounded(4);
        let _r = q.try_submit("m", req()).unwrap();
        q.begin_shutdown();
        assert!(q.is_shutting_down());
        assert_eq!(q.try_submit("m", req()).unwrap_err(), AdmissionError::ShuttingDown);
        assert_eq!(q.submit("m", req()).unwrap_err(), AdmissionError::ShuttingDown);
        // The marker is queued exactly once, behind the admitted job.
        assert!(matches!(rx.recv().unwrap(), Job::Linear(_)));
        assert!(matches!(rx.recv().unwrap(), Job::Shutdown));
        q.begin_shutdown(); // idempotent — no second marker
        assert!(matches!(rx.try_recv(), Err(mpsc::TryRecvError::Empty)));
    }

    #[test]
    fn depth_tracks_consumption() {
        let (q, rx) = AdmissionQueue::bounded(3);
        let _r1 = q.try_submit("m", req()).unwrap();
        let _r2 = q.try_submit("m", req()).unwrap();
        assert_eq!(q.depth(), 2);
        let _ = rx.recv().unwrap();
        assert_eq!(q.depth(), 1);
        let _ = rx.try_recv().unwrap();
        assert_eq!(q.depth(), 0);
        // Capacity freed: admission works again.
        let _r3 = q.try_submit("m", req()).unwrap();
        assert_eq!(q.depth(), 1);
    }

    /// Forward jobs ride the same bounded channel: they count toward the
    /// depth bound and decrement it on consumption, exactly like linears.
    #[test]
    fn forward_jobs_share_the_depth_bound() {
        let (q, rx) = AdmissionQueue::bounded(2);
        let _r1 = q.try_submit_forward("m", ForwardRequest { tokens: vec![1, 2] }).unwrap();
        let _r2 = q.try_submit("m", req()).unwrap();
        assert_eq!(q.depth(), 2);
        assert_eq!(
            q.try_submit_forward("m", ForwardRequest { tokens: vec![3] }).unwrap_err(),
            AdmissionError::Overloaded
        );
        assert!(matches!(rx.recv().unwrap(), Job::Forward(_)));
        assert_eq!(q.depth(), 1);
        q.begin_shutdown();
        assert_eq!(
            q.submit_forward("m", ForwardRequest { tokens: vec![0] }).unwrap_err(),
            AdmissionError::ShuttingDown
        );
    }

    #[test]
    fn dropped_receiver_reads_as_shutting_down() {
        let (q, rx) = AdmissionQueue::bounded(2);
        drop(rx);
        assert_eq!(q.try_submit("m", req()).unwrap_err(), AdmissionError::ShuttingDown);
    }
}
