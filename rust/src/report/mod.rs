//! Paper-style table rendering (Table I / Table II rows) shared by the CLI
//! and the bench targets, so every reproduction prints identically.

pub mod tables;

pub use tables::{render_storage, render_table1, render_table2, render_telemetry, StorageRow, Table1Row};
