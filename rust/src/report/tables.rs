//! Renderers that print results in the exact shape of the paper's tables.

use crate::quant::bits::{
    swsc_avg_bits, swsc_avg_bits_paper, swsc_params_for_bits, swsc_quantized_avg_bits,
};

/// One row of the Table-I reproduction.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub projector: String,
    pub method: String,
    pub avg_bits: f64,
    pub perplexity: f64,
}

/// Render the Table-I reproduction (paper §IV-B):
/// "THE PERPLEXITY RESULTS OF THE `<model>` COMPRESSED BY SWSC AND QUANTIZED
/// BY RTN".
pub fn render_table1(title: &str, fp32_ppl: f64, rows: &[Table1Row]) -> String {
    let mut out = String::new();
    out.push_str(&format!("TABLE I — {title}\n"));
    out.push_str(&format!("(uncompressed fp32 baseline perplexity: {:.3})\n", fp32_ppl));
    out.push_str("| Projector | Method | Avg. Bits | Perplexity |\n");
    out.push_str("|-----------|--------|-----------|------------|\n");
    let mut last_proj = String::new();
    let mut last_bits = f64::NAN;
    for r in rows {
        let proj = if r.projector == last_proj { String::new() } else { r.projector.clone() };
        let bits = if r.projector == last_proj && (r.avg_bits - last_bits).abs() < 1e-9 {
            String::new()
        } else {
            fmt_bits(r.avg_bits)
        };
        let ppl = if r.perplexity.is_nan() {
            "nan".to_string()
        } else if r.perplexity >= 1000.0 {
            format!("{:.0}", r.perplexity)
        } else {
            format!("{:.3}", r.perplexity)
        };
        out.push_str(&format!("| {:<9} | {:<6} | {:<9} | {:<10} |\n", proj, r.method, bits, ppl));
        last_proj = r.projector.clone();
        last_bits = r.avg_bits;
    }
    out
}

/// Render the Table-II reproduction (paper §IV-C): average bits vs number
/// of clusters and vs retained rank, for channel dimension `m`.
pub fn render_table2(m: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!("TABLE II — AVERAGE BITS vs CLUSTERS / RANK (m = {m})\n"));
    out.push_str("| Cluster | Avg Bits. | K (rank) | Avg Bits. |\n");
    out.push_str("|---------|-----------|----------|-----------|\n");
    // The paper's grid scaled to m: clusters at m/32, m/16, m/8;
    // ranks at m/64, m/32, m/16 — the same 0.5/1/2-bit points.
    let clusters = [m / 32, m / 16, m / 8];
    let ranks = [m / 64, m / 32, m / 16];
    for i in 0..3 {
        let cb = swsc_avg_bits_paper(m, clusters[i], 0);
        let rb = swsc_avg_bits_paper(m, 0, ranks[i]);
        out.push_str(&format!(
            "| {:<7} | {:<9} | {:<8} | {:<9} |\n",
            clusters[i], fmt_bits(cb), ranks[i], fmt_bits(rb)
        ));
    }
    out
}

/// One compressed (or double-compressed) entry of a written `.swsc`
/// container, for the storage summary.
#[derive(Debug, Clone)]
pub struct StorageRow {
    pub name: String,
    /// Original dense shape `(m, n)`.
    pub shape: (usize, usize),
    pub k: usize,
    pub rank: usize,
    /// Quantization group length for entries stored as grouped int8;
    /// `None` for fp16-factor entries.
    pub group: Option<usize>,
}

/// Render the storage accounting of a written container: per entry the
/// exact avg-bits estimate ([`swsc_avg_bits`] for fp16 factors,
/// [`swsc_quantized_avg_bits`] for grouped-int8 ones), then the
/// ground truth — actual serialized bytes over *all* original
/// parameters (`total_params`, dense ride-alongs included).
pub fn render_storage(rows: &[StorageRow], file_bytes: usize, total_params: usize) -> String {
    let mut out = String::new();
    out.push_str("STORAGE — avg bits per original parameter\n");
    out.push_str("| Matrix | Shape | k | r | Encoding | Avg Bits | B/param |\n");
    out.push_str("|--------|-------|---|---|----------|----------|---------|\n");
    for r in rows {
        let (m, n) = r.shape;
        let (enc, bits) = match r.group {
            Some(g) => (format!("int8/g{g}"), swsc_quantized_avg_bits(m, n, r.k, r.rank, g)),
            None => ("fp16".to_string(), swsc_avg_bits(m, n, r.k, r.rank)),
        };
        out.push_str(&format!(
            "| {:<6} | {m}x{n} | {} | {} | {enc:<8} | {:<8} | {:.3} |\n",
            r.name,
            r.k,
            r.rank,
            fmt_bits(bits.avg_bits),
            bits.avg_bits / 8.0,
        ));
    }
    let bpp = file_bytes as f64 / (total_params.max(1)) as f64;
    out.push_str(&format!(
        "file: {file_bytes} B over {total_params} params = {bpp:.3} B/param \
         ({:.2} avg bits, container overhead included)\n",
        bpp * 8.0
    ));
    out
}

/// Render the compression-quality telemetry summary (PR 10): one row per
/// matrix out of a [`CompressionReport`] — iteration count, final inertia,
/// leading error singular value, compensation energy at the retained rank,
/// and (for int8 containers) the worst quantization grid error. The full
/// per-iteration / per-σ data stays in the JSON artifact; this is the
/// human-scan view.
pub fn render_telemetry(rep: &crate::compress::CompressionReport) -> String {
    let mut out = String::new();
    out.push_str(&format!("TELEMETRY — compression quality (seed {})\n", rep.seed));
    out.push_str("| Matrix | Shape | k | r | Iters | Inertia | sigma_1 | Comp. Energy | Grid Err (max) |\n");
    out.push_str("|--------|-------|---|---|-------|---------|---------|--------------|----------------|\n");
    for m in &rep.matrices {
        let sigma1 =
            m.spectrum.first().map(|s| format!("{s:.3e}")).unwrap_or_else(|| "-".into());
        let grid = if m.grid_error_max > 0.0 {
            format!("{:.3e}", m.grid_error_max)
        } else {
            "-".into()
        };
        out.push_str(&format!(
            "| {:<6} | {}x{} | {} | {} | {} | {:.4e} | {sigma1} | {:.3} | {grid} |\n",
            m.name,
            m.shape.0,
            m.shape.1,
            m.clusters,
            m.rank,
            m.kmeans_iterations,
            m.inertia,
            m.compensation_energy,
        ));
    }
    out
}

/// Format a bits value compactly: integral values without decimals.
fn fmt_bits(b: f64) -> String {
    if (b - b.round()).abs() < 1e-9 {
        format!("{}", b.round() as i64)
    } else {
        format!("{b:.2}").trim_end_matches('0').trim_end_matches('.').to_string()
    }
}

/// Helper: the (k, r) grid used by the Table-I runs at a target budget.
pub fn table1_params(m: usize, target_bits: f64) -> (usize, usize) {
    swsc_params_for_bits(m, target_bits, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper_at_4096() {
        let t = render_table2(4096);
        assert!(t.contains("| 128     | 0.5"), "{t}");
        assert!(t.contains("| 256     | 1"), "{t}");
        assert!(t.contains("| 512     | 2"), "{t}");
        assert!(t.contains("| 64       | 0.5"), "{t}");
        assert!(t.contains("| 128      | 1"), "{t}");
        assert!(t.contains("| 256      | 2"), "{t}");
    }

    #[test]
    fn table1_renders_nan_and_grouping() {
        let rows = vec![
            Table1Row { projector: "Q".into(), method: "RTN".into(), avg_bits: 3.0, perplexity: 20.55 },
            Table1Row { projector: "Q".into(), method: "SWSC".into(), avg_bits: 3.0, perplexity: 6.547 },
            Table1Row { projector: "K".into(), method: "RTN".into(), avg_bits: 2.0, perplexity: f64::NAN },
        ];
        let t = render_table1("test", 5.5, &rows);
        assert!(t.contains("20.550"));
        assert!(t.contains("nan"));
        // Second Q row elides the projector cell.
        assert!(t.contains("|           | SWSC"));
    }

    #[test]
    fn storage_table_mixes_encodings_and_reports_actual_bytes() {
        let rows = vec![
            StorageRow { name: "wq".into(), shape: (256, 256), k: 32, rank: 8, group: None },
            StorageRow { name: "wk".into(), shape: (256, 256), k: 32, rank: 8, group: Some(64) },
        ];
        // 2 entries × 64 Ki params + a 64 Ki dense ride-along; pretend the
        // file serialized to 96 KiB → 0.5 B/param = 4 avg bits.
        let t = render_storage(&rows, 98304, 3 * 256 * 256);
        assert!(t.contains("| wq"), "{t}");
        assert!(t.contains("fp16"), "{t}");
        assert!(t.contains("int8/g64"), "{t}");
        assert!(t.contains("0.500 B/param"), "{t}");
        assert!(t.contains("4.00 avg bits"), "{t}");
        // The quantized estimate must come in under the fp16 one.
        let est16 = swsc_avg_bits(256, 256, 32, 8).avg_bits;
        let est8 = swsc_quantized_avg_bits(256, 256, 32, 8, 64).avg_bits;
        assert!(est8 < est16);
    }

    #[test]
    fn telemetry_table_renders_every_matrix() {
        use crate::compress::{CompressionReport, MatrixTelemetry};
        let rep = CompressionReport {
            seed: 9,
            matrices: vec![
                MatrixTelemetry {
                    name: "a.wq".into(),
                    shape: (64, 64),
                    clusters: 8,
                    rank: 4,
                    kmeans_iterations: 12,
                    inertia: 1.25,
                    spectrum: vec![2.5, 1.0],
                    compensation_energy: 0.75,
                    grid_error_max: 0.001,
                    ..Default::default()
                },
                MatrixTelemetry { name: "b.wk".into(), shape: (32, 32), ..Default::default() },
            ],
        };
        let t = render_telemetry(&rep);
        assert!(t.contains("seed 9"), "{t}");
        assert!(t.contains("| a.wq"), "{t}");
        assert!(t.contains("| b.wk"), "{t}");
        assert!(t.contains("2.500e0"), "{t}");
        assert!(t.contains("0.750"), "{t}");
        // No spectrum / no quantization render as dashes, not zeros.
        assert!(t.contains("| - |"), "{t}");
        assert_eq!(t.lines().count(), 2 + 1 + rep.matrices.len());
    }

    #[test]
    fn big_ppl_rendered_without_decimals() {
        let rows = vec![Table1Row {
            projector: "Q".into(),
            method: "RTN".into(),
            avg_bits: 2.0,
            perplexity: 4958.396,
        }];
        let t = render_table1("t", 5.0, &rows);
        assert!(t.contains("4958"), "{t}");
        assert!(!t.contains("4958.396"));
    }
}
