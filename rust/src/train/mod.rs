//! Training driver (L3): drives the AOT-compiled `train_step` executable.
//!
//! The step function (Adam + causal-LM loss, defined in
//! `python/compile/model.py`) takes the flat parameter list, the Adam
//! moments, the step counter, the learning rate, and a token batch; it
//! returns updated parameters/moments and the loss. Rust owns the loop:
//! LR schedule, logging, checkpointing. Python is never involved.

pub mod lr;

pub use lr::LrSchedule;

use crate::io::Checkpoint;
use crate::model::{param_specs, ModelConfig};
use crate::runtime::{tensor_to_literal, tokens_to_literal, Engine};
use crate::runtime::convert::literal_scalar_f32;
use crate::tensor::Tensor;
use crate::text::Batch;
use anyhow::{Context, Result};

/// Training loop state: parameters + Adam moments as XLA literals.
pub struct Trainer {
    engine: Engine,
    cfg: ModelConfig,
    params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    step: usize,
    /// Loss history (one entry per step).
    pub losses: Vec<f32>,
}

impl Trainer {
    /// Initialize from a parameter checkpoint (canonical order enforced).
    pub fn new(engine: Engine, cfg: ModelConfig, init: &Checkpoint) -> Result<Trainer> {
        engine.manifest().verify_config(&cfg)?;
        let specs = param_specs(&cfg);
        let mut params = Vec::with_capacity(specs.len());
        let mut m = Vec::with_capacity(specs.len());
        let mut v = Vec::with_capacity(specs.len());
        for spec in &specs {
            let t = init.get(&spec.name).with_context(|| format!("init missing param {}", spec.name))?;
            anyhow::ensure!(t.shape() == &spec.shape[..], "shape mismatch for {}", spec.name);
            params.push(tensor_to_literal(t)?);
            let zero = Tensor::zeros(&spec.shape);
            m.push(tensor_to_literal(&zero)?);
            v.push(tensor_to_literal(&zero)?);
        }
        Ok(Trainer { engine, cfg, params, m, v, step: 0, losses: Vec::new() })
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// Run one optimizer step; returns the loss.
    pub fn step(&mut self, batch: &Batch, lr: f32) -> Result<f32> {
        let exe = self.engine.load("train_step")?;
        let p = self.params.len();

        // Order must match python/compile/model.py::train_step signature:
        // (params..., m..., v..., step, lr, tokens, targets). Parameters
        // and moments are passed by reference — no host round trip (§Perf:
        // the old copy path cost ~55 MB of memcpy per step on `small`).
        let step_lit = xla::Literal::scalar(self.step as f32);
        let lr_lit = xla::Literal::scalar(lr);
        let tok_lit = tokens_to_literal(&batch.inputs, batch.batch, batch.seq)?;
        let tgt_lit = tokens_to_literal(&batch.targets, batch.batch, batch.seq)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(3 * p + 4);
        inputs.extend(self.params.iter().chain(&self.m).chain(&self.v));
        inputs.extend([&step_lit, &lr_lit, &tok_lit, &tgt_lit]);

        let mut outs = exe.run_refs(&inputs)?;
        anyhow::ensure!(outs.len() == 3 * p + 1, "train_step output arity {}", outs.len());
        let loss = literal_scalar_f32(&outs.pop().unwrap())?;
        let new_v = outs.split_off(2 * p);
        let new_m = outs.split_off(p);
        self.params = outs;
        self.m = new_m;
        self.v = new_v;
        self.step += 1;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Borrow the current parameters (for in-loop evaluation).
    pub fn params(&self) -> &[xla::Literal] {
        &self.params
    }

    /// Export current parameters to a host checkpoint.
    pub fn to_checkpoint(&self) -> Result<Checkpoint> {
        let mut ck = Checkpoint::new();
        for (spec, lit) in param_specs(&self.cfg).iter().zip(&self.params) {
            ck.insert(&spec.name, crate::runtime::literal_to_tensor(lit)?);
        }
        Ok(ck)
    }
}

