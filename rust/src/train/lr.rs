//! Learning-rate schedules.

/// Linear warmup followed by cosine decay to `min_lr`.
#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub base_lr: f32,
    pub min_lr: f32,
    pub warmup_steps: usize,
    pub total_steps: usize,
}

impl LrSchedule {
    pub fn new(base_lr: f32, warmup_steps: usize, total_steps: usize) -> Self {
        LrSchedule { base_lr, min_lr: base_lr * 0.1, warmup_steps, total_steps }
    }

    /// LR at `step` (0-based).
    pub fn at(&self, step: usize) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        if step >= self.total_steps {
            return self.min_lr;
        }
        let progress = (step - self.warmup_steps) as f32
            / (self.total_steps - self.warmup_steps).max(1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        self.min_lr + (self.base_lr - self.min_lr) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::new(1.0, 10, 100);
        assert!((s.at(0) - 0.1).abs() < 1e-6);
        assert!((s.at(4) - 0.5).abs() < 1e-6);
        assert!((s.at(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn decays_to_min() {
        let s = LrSchedule::new(1.0, 10, 100);
        assert!((s.at(10) - 1.0).abs() < 1e-5);
        assert!(s.at(55) < 1.0);
        assert!((s.at(100) - 0.1).abs() < 1e-6);
        assert!((s.at(5000) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn monotone_after_warmup() {
        let s = LrSchedule::new(3e-4, 20, 300);
        let mut last = f32::INFINITY;
        for step in 20..300 {
            let lr = s.at(step);
            assert!(lr <= last + 1e-9);
            last = lr;
        }
    }

    #[test]
    fn zero_warmup() {
        let s = LrSchedule::new(1.0, 0, 10);
        assert!((s.at(0) - 1.0).abs() < 1e-6);
    }
}
