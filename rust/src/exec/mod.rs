//! Deterministic parallel execution core.
//!
//! Every compression-time hot path (blocked matmul, Lloyd assign/update,
//! randomized-SVD GEMMs, the model-level compression driver) runs through
//! this module. The design goal is *bit-identical output at any thread
//! count*, which is what lets the rest of the repo treat parallelism as a
//! pure go-faster knob: property tests compare `threads = 1` against
//! `threads ∈ {2, 4, 8}` with exact equality, and a `.swsc` file produced
//! on a 64-core box byte-matches one produced on a laptop (the golden-file
//! test in `tests/golden_swsc.rs` pins exactly that).
//!
//! ## Why determinism is an invariant here
//!
//! SWSC compression is seeded end-to-end (k-means++ picks, randomized-SVD
//! sketches, per-matrix job seeds derived from the plan seed). A scheduler
//! that let thread count perturb float summation order would silently break
//! that contract: Table I numbers would stop being reproducible, the
//! L1-vs-L3 parity tests would need sloppy tolerances, and checkpoint
//! byte-diffs would be useless. Determinism is therefore treated as a hard
//! invariant, not a nice-to-have — the scheduling policy below is chosen so
//! that it costs us almost nothing.
//!
//! ## Deterministic chunked scheduling
//!
//! Work of size `n` is cut at **fixed chunk boundaries** that depend only
//! on `n` and the per-call chunk size — never on the thread count. Each
//! chunk either
//!
//! - writes into a **pre-assigned disjoint slot** (a row band of the output
//!   buffer, or element `i` of a result vector), or
//! - returns a **partial value** (e.g. a partial inertia sum) that the
//!   caller reduces **in chunk order**.
//!
//! Which worker executes which chunk is irrelevant: slots don't overlap and
//! reductions never happen in completion order. That freedom is what lets
//! chunks be claimed dynamically (an atomic counter) without touching
//! numerics. With `threads = 1` the chunks run in order on the calling
//! thread — the serial path is literally the same code.
//!
//! Note the guarantee is *identical output across thread counts*, with the
//! same fixed chunk layout everywhere. For independent outputs (matmul
//! rows, k-means labels) this is also bit-identical to an un-chunked serial
//! loop; for float reductions the per-chunk grouping is the canonical
//! order.
//!
//! ## The persistent worker pool
//!
//! Parallel submissions execute on a process-wide [`pool::WorkerPool`]:
//!
//! - **Lazy spawn.** The pool starts with zero threads. A submission that
//!   asks for `t` executors grows the pool until `t − 1` *idle* workers
//!   exist (workers busy on other jobs — e.g. the outer job of a nested
//!   submission — don't count), so demand from nested pipelines is met
//!   without ever respawning. Idle workers park on a condvar — no
//!   spinning.
//! - **Reuse, not respawn.** Submitting a job costs one short mutex
//!   critical section plus a wakeup (~µs), versus tens of µs *per worker
//!   per call* for the old scoped spawn-per-call scheme. That is why the
//!   pool backend affords finer-grained parallelism: the serial-fallback
//!   thresholds in `tensor::ops` are lower under [`ExecBackend::Pool`].
//! - **Shutdown on drop.** Dropping a pool flips a shutdown flag, wakes
//!   every worker, and joins them. The global pool is never dropped; the
//!   lifecycle is exercised by private pools in tests.
//! - **Panic isolation.** A panicking task poisons only its own job: the
//!   panic is re-thrown in the submitting thread once the batch drains,
//!   and the workers keep serving later jobs.
//! - **Nested submission.** A task may itself submit a job (the
//!   coordinator's per-matrix jobs do exactly this for their inner ops).
//!   The submitting thread always helps drain its own job, so nesting
//!   cannot deadlock even with every worker busy.
//!
//! ## Picking thread counts — `SWSC_THREADS` semantics
//!
//! [`ExecConfig::from_env`] resolves, in order: the `SWSC_THREADS`
//! environment variable, then `std::thread::available_parallelism()`, then
//! 1. The process-wide default is cached in [`global`]; APIs that need
//! explicit control (property tests, the bench thread sweep, the
//! coordinator's `--workers` flag) take an [`ExecConfig`] and everything
//! else delegates to the global one. `SWSC_THREADS` therefore bounds how
//! many workers *default-config* callers ever cause the pool to spawn; an
//! explicit `ExecConfig::with_threads(t)` may grow the pool past it (the
//! parity tests rely on this to exercise real parallelism even under
//! `SWSC_THREADS=1`). `SWSC_THREADS=1` makes every default-config call run
//! the inline serial reference path; tiny inputs always do, via the
//! `threads.min(chunks)` clamp.
//!
//! ## Backends
//!
//! [`ExecBackend::Pool`] (the default) runs batches on the persistent
//! pool; [`ExecBackend::SpawnPerCall`] is the old scoped-thread scheme,
//! kept so the bench harness can measure `pool_vs_spawn` on identical
//! workloads (and because it is a useful oracle: both backends share the
//! chunk contract, so their outputs must be bit-identical). Select with
//! [`set_backend`] or `SWSC_EXEC_BACKEND=spawn`.

pub mod pool;

pub use pool::panic_message;

use std::ops::Range;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// Hard ceiling on worker threads — a guard against absurd env values, not
/// a tuning knob.
pub const MAX_THREADS: usize = 256;

/// Thread-count configuration for the deterministic executor.
///
/// The thread count never affects results, only wall-clock; `threads = 1`
/// reproduces the serial path exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecConfig {
    /// Number of worker threads (including the calling thread). Always ≥ 1.
    pub threads: usize,
}

impl ExecConfig {
    /// Resolve from the environment: `SWSC_THREADS` if set and positive,
    /// otherwise the machine's available parallelism, otherwise 1.
    pub fn from_env() -> ExecConfig {
        let threads = std::env::var("SWSC_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&t| t > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        ExecConfig::with_threads(threads)
    }

    /// Single-threaded config — the reference serial path.
    pub fn serial() -> ExecConfig {
        ExecConfig { threads: 1 }
    }

    /// Explicit thread count (clamped to `1..=MAX_THREADS`).
    pub fn with_threads(threads: usize) -> ExecConfig {
        ExecConfig { threads: threads.clamp(1, MAX_THREADS) }
    }
}

impl Default for ExecConfig {
    fn default() -> Self {
        global()
    }
}

/// Process-wide default config, resolved from the environment once.
pub fn global() -> ExecConfig {
    static GLOBAL: OnceLock<ExecConfig> = OnceLock::new();
    *GLOBAL.get_or_init(ExecConfig::from_env)
}

/// Which execution engine carries parallel batches. Outputs are
/// bit-identical between backends — both obey the chunk contract — so this
/// is purely a wall-clock/bench knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecBackend {
    /// Persistent worker pool (default): spawn once, reuse forever.
    Pool,
    /// Scoped `std::thread` spawn per parallel call — the pre-pool scheme,
    /// kept as the bench baseline and as a cross-check oracle.
    SpawnPerCall,
}

// 0 = unresolved, 1 = Pool, 2 = SpawnPerCall.
static BACKEND: AtomicU8 = AtomicU8::new(0);

/// Current backend; first call resolves `SWSC_EXEC_BACKEND` (`"spawn"`
/// selects [`ExecBackend::SpawnPerCall`], anything else the pool).
pub fn backend() -> ExecBackend {
    match BACKEND.load(Ordering::Relaxed) {
        1 => ExecBackend::Pool,
        2 => ExecBackend::SpawnPerCall,
        _ => {
            let resolved = match std::env::var("SWSC_EXEC_BACKEND").ok().as_deref() {
                Some("spawn") => ExecBackend::SpawnPerCall,
                _ => ExecBackend::Pool,
            };
            set_backend(resolved);
            resolved
        }
    }
}

/// Override the backend process-wide. Intended for the bench harness and
/// for parity tests; safe to flip at any time because both backends
/// produce bit-identical outputs (only wall-clock changes).
pub fn set_backend(b: ExecBackend) {
    BACKEND.store(
        match b {
            ExecBackend::Pool => 1,
            ExecBackend::SpawnPerCall => 2,
        },
        Ordering::Relaxed,
    );
}

/// Fixed chunk boundaries for `n` items: `⌈n/chunk⌉` ranges of `chunk`
/// items (the last one ragged). Depends only on `n` and `chunk` — never on
/// the thread count — which is what makes the scheduling deterministic.
pub fn chunk_ranges(n: usize, chunk: usize) -> Vec<Range<usize>> {
    let chunk = chunk.max(1);
    (0..n).step_by(chunk).map(|s| s..(s + chunk).min(n)).collect()
}

/// Raw-pointer courier for pre-assigned disjoint slots. Soundness comes
/// from the claim discipline: every index is claimed exactly once, so no
/// two tasks ever touch the same slot. Access goes through [`SendPtr::at`]
/// so closures capture the `Sync` wrapper itself, never the bare `*mut T`
/// (2021-edition closures capture fields, and `*mut T` is not `Sync`).
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Pointer to element `i`. Caller guarantees `i` is in bounds and that
    /// no other thread touches it.
    unsafe fn at(&self, i: usize) -> *mut T {
        self.0.add(i)
    }
}

/// Spawn-per-call scheduling policy: deal `items` round-robin to `workers`
/// lists (worker `w` gets items `w, w + W, w + 2W, …`), run list 0 on the
/// calling thread and the rest on scoped threads. Callers guarantee
/// `workers ≥ 2`; item payloads carry their own pre-assigned destinations,
/// so which worker runs an item never affects results. Panics in `f`
/// propagate to the caller.
fn run_static<I, F>(workers: usize, items: Vec<I>, f: F)
where
    I: Send,
    F: Fn(I) + Sync,
{
    let mut per_worker: Vec<Vec<I>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        per_worker[i % workers].push(item);
    }
    std::thread::scope(|scope| {
        let f = &f;
        let mut lists = per_worker.into_iter();
        let mine = lists.next().unwrap();
        for work in lists {
            scope.spawn(move || {
                for item in work {
                    f(item);
                }
            });
        }
        for item in mine {
            f(item);
        }
    });
}

/// Map `0..m` to values, one pre-assigned output slot per index.
///
/// `f(i)` may run on any worker, but its result always lands in slot `i`,
/// so the returned vector is identical at every thread count (and between
/// backends). Panics in `f` propagate to the caller.
pub fn map_indexed<T, F>(cfg: ExecConfig, m: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = cfg.threads.min(m);
    if workers <= 1 {
        return (0..m).map(f).collect();
    }

    let mut slots: Vec<Option<T>> = (0..m).map(|_| None).collect();
    match backend() {
        ExecBackend::Pool => {
            let base = SendPtr(slots.as_mut_ptr());
            let task = |i: usize| {
                let v = f(i);
                // SAFETY: index i is claimed exactly once; slots are
                // disjoint and the Vec outlives the blocking `run` call.
                unsafe { *base.at(i) = Some(v) };
            };
            pool::global().run(workers, m, &task);
        }
        ExecBackend::SpawnPerCall => {
            let items: Vec<(usize, &mut Option<T>)> = slots.iter_mut().enumerate().collect();
            run_static(workers, items, |(i, slot)| *slot = Some(f(i)));
        }
    }
    slots.into_iter().map(|s| s.expect("exec: unfilled slot")).collect()
}

/// Like [`map_indexed`], but guaranteed to claim indices dynamically even
/// on the spawn backend (where plain `map_indexed` deals statically).
/// Results still land in pre-assigned slots, so the output is identical —
/// which worker ran an index never matters. Use this when items have very
/// uneven cost (e.g. whole-matrix compression jobs). On the pool backend
/// claiming is always dynamic, so this is the same code path as
/// [`map_indexed`].
pub fn map_indexed_balanced<T, F>(cfg: ExecConfig, m: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = cfg.threads.min(m);
    if workers <= 1 {
        return (0..m).map(f).collect();
    }
    if backend() == ExecBackend::Pool {
        return map_indexed(cfg, m, f);
    }
    let slots: Vec<Mutex<Option<T>>> = (0..m).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let (f, slots, next) = (&f, &slots, &next);
        let run = move || loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= m {
                break;
            }
            *slots[i].lock().unwrap() = Some(f(i));
        };
        for _ in 1..workers {
            scope.spawn(run);
        }
        run();
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("exec: unfilled slot"))
        .collect()
}

/// Map fixed chunks of `0..n` to values, returned in chunk order.
///
/// The canonical shape for deterministic reductions: compute a partial per
/// chunk, then fold the returned vector front-to-back.
pub fn map_chunks<T, F>(cfg: ExecConfig, n: usize, chunk: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    let ranges = chunk_ranges(n, chunk);
    map_indexed(cfg, ranges.len(), |i| f(ranges[i].clone()))
}

/// Deterministic bounded-memory chunk reduction: [`map_chunks`] followed by
/// an in-order fold, but with at most `cfg.threads` partials alive at once.
/// Chunk boundaries and fold order are fixed, so results are bit-identical
/// at any thread count; only how many partials coexist in memory varies.
/// Use this when partials are large (e.g. k×m centroid sums) and full
/// materialization would be gigabytes on wide matrices.
pub fn fold_chunks<T, F, G>(cfg: ExecConfig, n: usize, chunk: usize, map: F, mut fold: G)
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
    G: FnMut(T),
{
    let ranges = chunk_ranges(n, chunk);
    for wave in ranges.chunks(cfg.threads.max(1)) {
        for partial in map_indexed(cfg, wave.len(), |i| map(wave[i].clone())) {
            fold(partial);
        }
    }
}

/// Run `f` over fixed row bands of a mutable `rows × row_len` buffer.
///
/// `data` is split every `rows_per_chunk` rows; `f(first_row, band)` gets
/// the band's starting row index and its disjoint `&mut` slice. Bands never
/// alias, so no synchronization is needed and the write pattern is
/// identical at every thread count.
pub fn for_row_bands<T, F>(
    cfg: ExecConfig,
    data: &mut [T],
    rows: usize,
    row_len: usize,
    rows_per_chunk: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(data.len(), rows * row_len, "band buffer is not rows × row_len");
    if rows == 0 {
        return;
    }
    let rpc = rows_per_chunk.max(1);
    let n_bands = rows.div_ceil(rpc);
    let workers = cfg.threads.min(n_bands);

    if workers <= 1 {
        for first_row in (0..rows).step_by(rpc) {
            let take = rpc.min(rows - first_row);
            f(first_row, &mut data[first_row * row_len..(first_row + take) * row_len]);
        }
        return;
    }

    match backend() {
        ExecBackend::Pool => {
            let base = SendPtr(data.as_mut_ptr());
            let task = |i: usize| {
                let first_row = i * rpc;
                let take = rpc.min(rows - first_row);
                // SAFETY: band i covers rows [i·rpc, i·rpc + take), claimed
                // exactly once; bands are disjoint and within `data`.
                let band = unsafe {
                    std::slice::from_raw_parts_mut(base.at(first_row * row_len), take * row_len)
                };
                f(first_row, band);
            };
            pool::global().run(workers, n_bands, &task);
        }
        ExecBackend::SpawnPerCall => {
            let mut bands: Vec<(usize, &mut [T])> = Vec::with_capacity(n_bands);
            let mut rest = data;
            let mut row = 0;
            while row < rows {
                let take = rpc.min(rows - row);
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(take * row_len);
                bands.push((row, head));
                rest = tail;
                row += take;
            }
            run_static(workers, bands, |(first_row, band)| f(first_row, band));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Run `body` under both backends, restoring the pool default after.
    /// Safe even with other tests running concurrently: backends are
    /// bit-identical, so a transient global flip only changes wall-clock.
    fn with_both_backends(body: impl Fn(ExecBackend)) {
        for b in [ExecBackend::Pool, ExecBackend::SpawnPerCall] {
            set_backend(b);
            body(b);
        }
        set_backend(ExecBackend::Pool);
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        assert_eq!(chunk_ranges(10, 4), vec![0..4, 4..8, 8..10]);
        assert_eq!(chunk_ranges(0, 4), Vec::<Range<usize>>::new());
        assert_eq!(chunk_ranges(3, 100), vec![0..3]);
        // chunk = 0 is clamped, not an infinite loop
        assert_eq!(chunk_ranges(2, 0), vec![0..1, 1..2]);
    }

    #[test]
    fn map_indexed_preserves_slot_order() {
        with_both_backends(|b| {
            for threads in [1, 2, 4, 8] {
                let got = map_indexed(ExecConfig::with_threads(threads), 37, |i| i * i);
                let want: Vec<usize> = (0..37).map(|i| i * i).collect();
                assert_eq!(got, want, "threads = {threads}, backend {b:?}");
            }
        });
    }

    #[test]
    fn map_indexed_runs_every_index_once() {
        with_both_backends(|b| {
            let hits = AtomicUsize::new(0);
            let out = map_indexed(ExecConfig::with_threads(4), 100, |i| {
                hits.fetch_add(1, Ordering::Relaxed);
                i
            });
            assert_eq!(hits.load(Ordering::Relaxed), 100, "backend {b:?}");
            assert_eq!(out.len(), 100);
        });
    }

    #[test]
    fn map_indexed_balanced_preserves_slot_order() {
        with_both_backends(|b| {
            for threads in [1, 2, 4, 8] {
                let got = map_indexed_balanced(ExecConfig::with_threads(threads), 53, |i| i * 3);
                let want: Vec<usize> = (0..53).map(|i| i * 3).collect();
                assert_eq!(got, want, "threads = {threads}, backend {b:?}");
            }
        });
    }

    #[test]
    fn map_chunks_reduces_in_fixed_order() {
        // Partial sums per chunk, folded front-to-back, must not depend on
        // the thread count — the bit-for-bit guarantee the pipeline uses.
        let xs: Vec<f64> = (0..1000).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let reduce = |threads: usize| -> f64 {
            map_chunks(ExecConfig::with_threads(threads), xs.len(), 64, |r| {
                r.map(|i| xs[i]).sum::<f64>()
            })
            .iter()
            .sum()
        };
        let base = reduce(1);
        with_both_backends(|b| {
            for threads in [2, 3, 4, 8] {
                assert_eq!(
                    base.to_bits(),
                    reduce(threads).to_bits(),
                    "threads = {threads}, backend {b:?}"
                );
            }
        });
    }

    #[test]
    fn fold_chunks_matches_map_chunks_bitwise() {
        let xs: Vec<f64> = (0..777).map(|i| 1.0 / (3.0 + i as f64)).collect();
        let full: f64 = map_chunks(ExecConfig::serial(), xs.len(), 50, |r| {
            r.map(|i| xs[i]).sum::<f64>()
        })
        .iter()
        .sum();
        for threads in [1, 2, 4, 8] {
            let mut folded = 0.0f64;
            fold_chunks(
                ExecConfig::with_threads(threads),
                xs.len(),
                50,
                |r| r.map(|i| xs[i]).sum::<f64>(),
                |p| folded += p,
            );
            assert_eq!(full.to_bits(), folded.to_bits(), "threads = {threads}");
        }
    }

    #[test]
    fn row_bands_write_disjoint_slots() {
        with_both_backends(|b| {
            for threads in [1, 2, 4, 8] {
                let (rows, row_len) = (23, 7);
                let mut buf = vec![0u32; rows * row_len];
                for_row_bands(
                    ExecConfig::with_threads(threads),
                    &mut buf,
                    rows,
                    row_len,
                    4,
                    |r0, band| {
                        for (off, v) in band.iter_mut().enumerate() {
                            *v = (r0 * row_len + off) as u32;
                        }
                    },
                );
                let want: Vec<u32> = (0..rows * row_len).map(|i| i as u32).collect();
                assert_eq!(buf, want, "threads = {threads}, backend {b:?}");
            }
        });
    }

    #[test]
    fn empty_work_is_fine() {
        with_both_backends(|_| {
            assert!(map_indexed(ExecConfig::with_threads(4), 0, |i| i).is_empty());
            let mut empty: Vec<f32> = Vec::new();
            for_row_bands(ExecConfig::with_threads(4), &mut empty, 0, 5, 8, |_, _| {
                panic!("no bands expected")
            });
        });
    }

    #[test]
    fn chunks_larger_than_items() {
        // chunk > n collapses to one chunk → inline serial, on any backend
        // and at any thread count.
        with_both_backends(|b| {
            for threads in [1, 4, 8] {
                let got = map_chunks(ExecConfig::with_threads(threads), 3, 100, |r| r.len());
                assert_eq!(got, vec![3], "threads = {threads}, backend {b:?}");
                let mut buf = vec![0u8; 6];
                for_row_bands(ExecConfig::with_threads(threads), &mut buf, 3, 2, 100, |r0, band| {
                    assert_eq!((r0, band.len()), (0, 6));
                    band.fill(1);
                });
                assert_eq!(buf, vec![1; 6], "backend {b:?}");
            }
        });
    }

    #[test]
    fn nested_map_indexed_from_worker() {
        // A parallel map whose tasks themselves run parallel maps — the
        // shape the coordinator's per-matrix jobs create. Must not deadlock
        // and must keep slot order on both backends.
        with_both_backends(|b| {
            let got = map_indexed(ExecConfig::with_threads(4), 6, |i| {
                map_indexed(ExecConfig::with_threads(4), 5, move |j| i * 10 + j)
            });
            for (i, inner) in got.iter().enumerate() {
                let want: Vec<usize> = (0..5).map(|j| i * 10 + j).collect();
                assert_eq!(inner, &want, "outer {i}, backend {b:?}");
            }
        });
    }

    #[test]
    fn pool_survives_panicking_job() {
        // Poisoned-job isolation end-to-end through the public API: a
        // panicking map must panic the caller, and the executor must stay
        // usable afterwards.
        let r = std::panic::catch_unwind(|| {
            map_indexed(ExecConfig::with_threads(4), 32, |i| {
                if i == 13 {
                    panic!("boom");
                }
                i
            })
        });
        assert!(r.is_err(), "panic must propagate to the submitter");
        let got = map_indexed(ExecConfig::with_threads(4), 64, |i| i + 1);
        let want: Vec<usize> = (0..64).map(|i| i + 1).collect();
        assert_eq!(got, want, "executor unusable after a poisoned job");
    }

    #[test]
    fn backends_bitwise_identical_on_float_reduction() {
        let xs: Vec<f64> = (0..2048).map(|i| (1.0f64 / (2.0 + i as f64)).sqrt()).collect();
        let sum_with = |threads: usize| -> f64 {
            map_chunks(ExecConfig::with_threads(threads), xs.len(), 37, |r| {
                r.map(|i| xs[i]).sum::<f64>()
            })
            .iter()
            .sum()
        };
        set_backend(ExecBackend::Pool);
        let pool = sum_with(8);
        set_backend(ExecBackend::SpawnPerCall);
        let spawn = sum_with(8);
        set_backend(ExecBackend::Pool);
        assert_eq!(pool.to_bits(), spawn.to_bits());
    }

    #[test]
    fn env_override_and_clamps() {
        assert_eq!(ExecConfig::with_threads(0).threads, 1);
        assert_eq!(ExecConfig::with_threads(100_000).threads, MAX_THREADS);
        assert!(ExecConfig::from_env().threads >= 1);
        assert_eq!(ExecConfig::serial().threads, 1);
    }
}
