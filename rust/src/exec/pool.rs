//! Persistent worker pool backing the deterministic executor.
//!
//! Workers are OS threads spawned **once** (lazily, on the first parallel
//! submission that needs them) and parked on a shared injector until jobs
//! arrive. A *job* is a batch of `m` independent index-tasks sharing one
//! task function; tasks are claimed by atomic counter, which is safe for
//! determinism because every task writes to a pre-assigned output slot —
//! which thread runs a task never affects results (see the [`crate::exec`]
//! module docs for the full contract).
//!
//! Design points:
//!
//! - **Submitter always participates.** The thread that calls
//!   [`WorkerPool::run`] claims tasks from its own job alongside the
//!   helpers. This is what makes *nested* submission (a worker's task
//!   submitting a sub-job) deadlock-free: even if every other worker is
//!   busy, the submitter drives its own job to completion, and waiting is
//!   only ever on strictly-newer jobs, so there is no cycle.
//! - **Per-job helper caps.** A job carries the caller's thread budget;
//!   workers that would exceed it skip the job. That is how `ExecConfig`
//!   thread counts stay a pure wall-clock knob on a shared pool.
//! - **Poisoned-job isolation.** Worker task bodies run under
//!   `catch_unwind`; the first panic payload is stashed on the job and
//!   re-thrown *in the submitting thread* after the batch drains. The
//!   workers themselves survive and keep serving later jobs.
//! - **Shutdown on drop.** Dropping a [`WorkerPool`] (only non-global pools
//!   in tests — the process-wide pool lives forever) flips a shutdown flag,
//!   wakes everyone, and joins the workers. By contract no jobs are in
//!   flight at drop time: submitters block inside `run` until their job
//!   drains, so holding `&pool` across `drop` is impossible.
//!
//! The injector is a short-critical-section `Mutex<Vec<Arc<Job>>>` plus a
//! `Condvar` — not a lock-free deque, but the lock is held only to push,
//! scan, or prune, never while running tasks; submission cost is a few
//! microseconds against the tens-of-microseconds-per-thread cost of the old
//! scoped spawn-per-call scheme.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use super::MAX_THREADS;

/// One batch of `m` index-tasks over a shared task function.
///
/// `func` is a type- and lifetime-erased pointer into the submitter's
/// stack; it is only dereferenced for claimed indices `i < m`, and the
/// submitter does not return from [`WorkerPool::run`] until `pending`
/// reaches zero, so the pointee outlives every dereference.
struct Job {
    func: *const (dyn Fn(usize) + Sync),
    m: usize,
    /// Next unclaimed task index (may overshoot `m`; claims ≥ `m` are
    /// no-ops).
    next: AtomicUsize,
    /// Claimed-but-unfinished plus unclaimed tasks; 0 ⇒ batch fully done.
    pending: AtomicUsize,
    /// Workers currently helping (submitter not counted).
    helpers: AtomicUsize,
    /// Max workers allowed to help (thread budget minus the submitter).
    helper_cap: usize,
    /// First panic payload from any task, re-thrown by the submitter.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: `func` is only shared between threads while the submitter keeps
// the referent alive (it blocks in `run` until `pending == 0`), and the
// pointee is `Sync`.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and run tasks until none are left; the thread that finishes
    /// the batch's last pending task flips `done` and wakes the submitter.
    fn work(&self) {
        let mut executed = 0u64;
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.m {
                // One counter add per drained batch, not per task —
                // observation only (PR 10).
                crate::obs::prof::counters::pool_tasks(executed);
                return;
            }
            executed += 1;
            // SAFETY: i < m, so the submitter is still blocked in `run`
            // and the closure is alive.
            let f = unsafe { &*self.func };
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            // AcqRel keeps every task's writes in the release sequence, so
            // the submitter's final Acquire load sees all output slots.
            if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                let mut done = self.done.lock().unwrap();
                *done = true;
                self.done_cv.notify_all();
            }
        }
    }

    fn fully_claimed(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.m
    }
}

struct Injector {
    /// Jobs with unclaimed tasks. Pruned lazily by whoever holds the lock.
    queue: Vec<Arc<Job>>,
    shutdown: bool,
    /// Worker threads spawned so far (for lazy growth and drop-join).
    handles: Vec<JoinHandle<()>>,
    /// Workers currently executing a job (not parked, not scanning). Lazy
    /// growth sizes against *idle* workers (`handles.len() - busy`), so
    /// nested submissions — whose outer jobs occupy workers — still get
    /// helpers up to their own budget instead of finding the pool "already
    /// big enough" but fully occupied.
    busy: usize,
    /// Cumulative wall time workers have spent executing jobs, in
    /// nanoseconds — an observability gauge (PR 9), sampled by the
    /// serving layer's exporters. Measured *around* `Job::work`, never
    /// inside it: timing is pure observation and cannot move bits.
    busy_nanos: u64,
}

/// A persistent pool of worker threads serving deterministic chunk batches.
///
/// Use [`global`] for the process-wide pool; constructing a private pool is
/// only useful in tests (lifecycle coverage) and always allowed.
pub struct WorkerPool {
    inj: Arc<(Mutex<Injector>, Condvar)>,
    /// Hard cap on workers this pool will ever spawn.
    max_workers: usize,
}

impl WorkerPool {
    /// Empty pool that will lazily grow up to `max_workers` helper threads.
    pub fn new(max_workers: usize) -> WorkerPool {
        WorkerPool {
            inj: Arc::new((
                Mutex::new(Injector {
                    queue: Vec::new(),
                    shutdown: false,
                    handles: Vec::new(),
                    busy: 0,
                    busy_nanos: 0,
                }),
                Condvar::new(),
            )),
            max_workers: max_workers.min(MAX_THREADS),
        }
    }

    /// Number of worker threads currently spawned (excludes submitters).
    pub fn workers_spawned(&self) -> usize {
        self.inj.0.lock().unwrap().handles.len()
    }

    /// Number of workers executing a job right now (excludes submitters).
    pub fn workers_busy(&self) -> usize {
        self.inj.0.lock().unwrap().busy
    }

    /// Cumulative worker busy time in nanoseconds (monotone; excludes
    /// submitter participation). Sampled as a gauge by the serving
    /// layer's metric exporters — `busy_nanos / (workers_spawned ·
    /// elapsed)` is pool utilization.
    pub fn busy_nanos(&self) -> u64 {
        self.inj.0.lock().unwrap().busy_nanos
    }

    /// Run `m` index-tasks with at most `threads` concurrent executors
    /// (including the calling thread). Blocks until every task has run;
    /// re-throws the first task panic, if any, after the batch drains.
    ///
    /// Which thread runs which index is unspecified — callers must give
    /// every task a pre-assigned disjoint output slot (the executor-facing
    /// wrappers in [`crate::exec`] all do).
    pub fn run(&self, threads: usize, m: usize, f: &(dyn Fn(usize) + Sync)) {
        if m == 0 {
            return;
        }
        if threads <= 1 || m == 1 {
            // Inline serial path: literally the same code a worker runs.
            for i in 0..m {
                f(i);
            }
            crate::obs::prof::counters::pool_tasks(m as u64);
            return;
        }
        let helper_cap = (threads - 1).min(self.max_workers);
        let job = Arc::new(Job {
            // Lifetime erasure happens here (raw pointers carry none); the
            // referent stays alive because `run` blocks until the batch
            // drains, see the `Job` docs.
            func: f as *const (dyn Fn(usize) + Sync),
            m,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(m),
            helpers: AtomicUsize::new(0),
            helper_cap,
            panic: Mutex::new(None),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });

        {
            let (lock, cv) = &*self.inj;
            let mut inj = lock.lock().unwrap();
            // Lazy spawn: this job can use `want_idle` helpers, and only
            // idle workers can help it — workers busy on other jobs (e.g.
            // the outer job of a nested submission) don't count. Grow until
            // enough idle workers exist or the pool cap is hit.
            let want_idle = helper_cap.min(m.saturating_sub(1));
            while inj.handles.len() < self.max_workers
                && inj.handles.len() - inj.busy < want_idle
            {
                let arc = Arc::clone(&self.inj);
                inj.handles.push(std::thread::spawn(move || worker_loop(&arc)));
            }
            inj.queue.push(Arc::clone(&job));
            cv.notify_all();
        }

        // The submitter is always executor #1 of its own job.
        job.work();

        // Wait for helpers still running claimed tasks.
        {
            let mut done = job.done.lock().unwrap();
            while !*done && job.pending.load(Ordering::Acquire) != 0 {
                done = job.done_cv.wait(done).unwrap();
            }
        }
        // Synchronize with every task's Release decrement (release sequence
        // on `pending`), making all slot writes visible here.
        debug_assert_eq!(job.pending.load(Ordering::Acquire), 0);

        // Prune our job if no worker got to it (cheap; avoids unbounded
        // queue growth when workers are saturated elsewhere).
        {
            let (lock, _) = &*self.inj;
            let mut inj = lock.lock().unwrap();
            inj.queue.retain(|j| !j.fully_claimed());
        }

        if let Some(payload) = job.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        let handles = {
            let (lock, cv) = &*self.inj;
            let mut inj = lock.lock().unwrap();
            inj.shutdown = true;
            cv.notify_all();
            std::mem::take(&mut inj.handles)
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(inj: &Arc<(Mutex<Injector>, Condvar)>) {
    let (lock, cv) = &**inj;
    let mut guard = lock.lock().unwrap();
    loop {
        if guard.shutdown {
            return;
        }
        // Find a job with unclaimed tasks and a free helper slot.
        guard.queue.retain(|j| !j.fully_claimed());
        let picked = guard.queue.iter().find_map(|j| {
            let prev = j.helpers.fetch_add(1, Ordering::Relaxed);
            if prev < j.helper_cap {
                Some(Arc::clone(j))
            } else {
                j.helpers.fetch_sub(1, Ordering::Relaxed);
                None
            }
        });
        match picked {
            Some(job) => {
                guard.busy += 1;
                drop(guard);
                let t0 = std::time::Instant::now();
                job.work();
                let spent = t0.elapsed();
                job.helpers.fetch_sub(1, Ordering::Relaxed);
                guard = lock.lock().unwrap();
                guard.busy -= 1;
                guard.busy_nanos = guard.busy_nanos.saturating_add(spent.as_nanos() as u64);
            }
            None => {
                // Woke (or first scan) and found nothing claimable —
                // either every job's helper slots are taken or the queue
                // is empty. High rates next to low pool_tasks mean the
                // fan-out is too fine for the pool (PR 10 counter).
                crate::obs::prof::counters::pool_steal_miss();
                guard = cv.wait(guard).unwrap();
            }
        }
    }
}

/// Best-effort extraction of a panic payload's human-readable message.
///
/// `panic!("...")` payloads are `&'static str`; `panic!("{x}")` and
/// `std::panic::panic_any(String)` payloads are `String`; anything else
/// (custom `panic_any` values) is opaque and yields `None`. The pool
/// re-throws the *original* payload via `resume_unwind`, so callers that
/// contain it (e.g. the serving layer's `ServeError::Panicked`) use this
/// to carry the original message instead of a generic "panicked".
pub fn panic_message(payload: &(dyn Any + Send)) -> Option<&str> {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        Some(s)
    } else {
        payload.downcast_ref::<String>().map(|s| s.as_str())
    }
}

/// The process-wide pool. Spawned lazily: creating it allocates no threads;
/// workers appear on the first parallel submission and are then reused for
/// the life of the process (it is never dropped, so "shutdown on drop" only
/// applies to private pools).
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(MAX_THREADS - 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_runs_all_tasks_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run(4, 100, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_and_single_task_jobs() {
        let pool = WorkerPool::new(4);
        pool.run(4, 0, &|_| panic!("no tasks expected"));
        let ran = AtomicUsize::new(0);
        pool.run(4, 1, &|i| {
            assert_eq!(i, 0);
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn lazy_spawn_and_helper_cap() {
        let pool = WorkerPool::new(8);
        assert_eq!(pool.workers_spawned(), 0, "no threads before first submission");
        pool.run(3, 64, &|_| {});
        // threads=3 ⇒ exactly 2 helpers wanted on first submission.
        assert!(pool.workers_spawned() <= 2, "spawned {}", pool.workers_spawned());
        pool.run(5, 64, &|_| {});
        // Growth sizes against *idle* workers; helpers from the previous
        // job may not have re-parked yet (busy is decremented lazily), so
        // the bound is want_idle (4) on top of the existing 2, never the
        // per-pool cap.
        assert!(pool.workers_spawned() <= 6, "spawned {}", pool.workers_spawned());
    }

    #[test]
    fn nested_submit_from_worker_task() {
        let pool = WorkerPool::new(4);
        let total = AtomicUsize::new(0);
        pool.run(4, 8, &|_| {
            pool.run(4, 8, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn panicking_job_poisons_only_itself() {
        let pool = WorkerPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, 16, &|i| {
                if i == 7 {
                    panic!("task 7 exploded");
                }
            });
        }));
        assert!(r.is_err(), "panic must reach the submitter");
        // Pool must still serve jobs afterwards.
        let hits = AtomicUsize::new(0);
        pool.run(4, 32, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(4);
        pool.run(4, 16, &|_| {});
        drop(pool); // must not hang
    }

    /// The PR 9 observability gauges: busy time accumulates once workers
    /// have actually executed, busy count returns to 0 when idle, and
    /// neither gauge perturbs results (same tasks, same slots).
    #[test]
    fn busy_gauges_accumulate() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.busy_nanos(), 0);
        assert_eq!(pool.workers_busy(), 0);
        for _ in 0..4 {
            pool.run(4, 64, &|_| {
                std::thread::sleep(std::time::Duration::from_micros(50));
            });
        }
        // The submitter always participates, but with 64 sleepy tasks and
        // 3 helper slots some worker executed something.
        assert!(pool.busy_nanos() > 0, "helpers ran jobs, busy time must accumulate");
        // All jobs drained before `run` returned ⇒ busy drains back to 0
        // (workers may briefly hold the decrement; spin a moment).
        for _ in 0..1000 {
            if pool.workers_busy() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
        assert_eq!(pool.workers_busy(), 0);
    }

    /// The re-thrown payload carries the original message, extractable by
    /// `panic_message` for both formatted (`String`) and literal
    /// (`&'static str`) panics; opaque payloads yield `None`.
    #[test]
    fn panic_message_survives_the_rethrow() {
        let pool = WorkerPool::new(4);
        let payload = catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, 8, &|i| {
                if i == 2 {
                    panic!("task {} exploded", 40 + 2);
                }
            });
        }))
        .unwrap_err();
        assert_eq!(panic_message(payload.as_ref()), Some("task 42 exploded"));

        let payload = catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, 8, &|i| {
                if i == 0 {
                    panic!("literal boom");
                }
            });
        }))
        .unwrap_err();
        assert_eq!(panic_message(payload.as_ref()), Some("literal boom"));

        let payload = catch_unwind(|| std::panic::panic_any(7u32)).unwrap_err();
        assert_eq!(panic_message(payload.as_ref()), None);
    }
}
