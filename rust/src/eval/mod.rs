//! Perplexity evaluation — over the AOT `fwd_eval` executable, or (PR 7)
//! entirely in the compressed domain.
//!
//! `fwd_eval(params..., tokens, targets)` returns per-row negative
//! log-likelihood sums and per-row token counts; perplexity is
//! `exp(Σ nll / Σ tokens)` over the eval stream — the same quantity the
//! paper reports on WikiText-2.
//!
//! [`perplexity_swsc_compressed`] computes the identical quantity with
//! **no PJRT, no artifacts, and no reconstructed weights**: the whole
//! forward runs through [`CompressedForward`], every linear served from
//! the factored form `R[labels] + A·B`. This closes PR 4's documented
//! caveat that `fwd_eval`'s contract is dense literals — perplexity of a
//! `.swsc` container no longer needs the weights restored host-side.

use crate::exec::ExecConfig;
use crate::infer::{CompressedForward, CompressedModel, InferMode};
use crate::io::{Checkpoint, SwscFile};
use crate::model::{param_specs, ModelConfig, ParamSpec};
use crate::runtime::{literal_to_tensor, tensor_to_literal, tokens_to_literal, Engine};
use crate::tensor::Tensor;
use crate::text::Dataset;
use anyhow::{Context, Result};
use std::sync::Arc;

/// The one place a resolved parameter tensor is checked against its spec —
/// shared by every param source (checkpoint, `.swsc`) so the error shape
/// can never drift between surfaces.
fn ensure_spec_shape(spec: &ParamSpec, t: &Tensor) -> Result<()> {
    anyhow::ensure!(
        t.shape() == &spec.shape[..],
        "param {} shape {:?} != {:?}",
        spec.name,
        t.shape(),
        spec.shape
    );
    Ok(())
}

/// Dense parameter tensors for `cfg`, restored from a `.swsc` container in
/// canonical [`param_specs`] order with shape validation. Shared by
/// [`Evaluator::params_from_swsc`] and the serving front's PJRT path
/// (`coordinator::EvalService::start_with_swsc`).
pub fn restore_param_tensors(file: &SwscFile, cfg: &ModelConfig) -> Result<Vec<Tensor>> {
    let mut restored = file.restore_all();
    let mut out = Vec::new();
    for spec in param_specs(cfg) {
        let t = restored
            .remove(&spec.name)
            .with_context(|| format!("swsc container missing {}", spec.name))?;
        ensure_spec_shape(&spec, &t)?;
        out.push(t);
    }
    Ok(out)
}

/// Full-dataset perplexity through an already-built compressed forward —
/// the building block of [`perplexity_swsc_compressed`], exposed so a
/// serving deployment can reuse the forward (and its lazily packed
/// panels) it already holds.
///
/// Windows are scored independently (`nll_window` per dataset row), so
/// the result is bit-for-bit independent of batch shape *and* of
/// `SWSC_THREADS` — the same determinism contract as the serving layer.
pub fn perplexity_compressed(
    fwd: &CompressedForward,
    data: &Dataset,
    exec: ExecConfig,
) -> Result<EvalResult> {
    let mut total_nll = 0.0f64;
    let mut total_tok = 0usize;
    let mut batches = 0usize;
    for batch in data.iter() {
        for row in 0..batch.batch {
            let s = row * batch.seq;
            let inputs: Vec<u32> =
                batch.inputs[s..s + batch.seq].iter().map(|&t| t as u32).collect();
            let targets: Vec<u32> =
                batch.targets[s..s + batch.seq].iter().map(|&t| t as u32).collect();
            let (nll, n) = fwd.nll_window(&inputs, &targets, exec)?;
            total_nll += nll;
            total_tok += n;
        }
        batches += 1;
    }
    anyhow::ensure!(batches > 0, "eval dataset produced no batches");
    let nll_per_token = total_nll / total_tok.max(1) as f64;
    Ok(EvalResult { perplexity: nll_per_token.exp(), nll_per_token, tokens: total_tok, batches })
}

/// Perplexity of a `.swsc` container served **from the compressed
/// domain** (PR 7): builds a [`CompressedForward`] in `mode` and scores
/// the eval stream through it. Needs no PJRT engine and no artifacts —
/// compare [`Evaluator::perplexity_of_swsc`], whose `fwd_eval` contract
/// restores dense literals host-side.
///
/// [`InferMode::Reconstructed`] is the in-tree dense oracle: identical
/// factors materialized once at load, so compressed-vs-reconstructed
/// agreement is an accumulation-order question, not a quality one.
pub fn perplexity_swsc_compressed(
    file: &SwscFile,
    cfg: &ModelConfig,
    mode: InferMode,
    data: &Dataset,
    exec: ExecConfig,
) -> Result<EvalResult> {
    let model = Arc::new(CompressedModel::from_file(file, mode));
    let fwd = CompressedForward::new(model, cfg.clone())?;
    perplexity_compressed(&fwd, data, exec)
}

/// Perplexity evaluator bound to one engine + model config.
pub struct Evaluator {
    engine: Engine,
    cfg: ModelConfig,
}

/// Result of an eval pass.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub perplexity: f64,
    pub nll_per_token: f64,
    pub tokens: usize,
    pub batches: usize,
}

impl Evaluator {
    pub fn new(engine: Engine, cfg: ModelConfig) -> Result<Evaluator> {
        engine.manifest().verify_config(&cfg)?;
        Ok(Evaluator { engine, cfg })
    }

    /// Convert a checkpoint into the canonical literal argument list.
    pub fn params_from_checkpoint(&self, ck: &Checkpoint) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::new();
        for spec in param_specs(&self.cfg) {
            let t = ck
                .get(&spec.name)
                .with_context(|| format!("checkpoint missing {}", spec.name))?;
            ensure_spec_shape(&spec, t)?;
            out.push(tensor_to_literal(t)?);
        }
        Ok(out)
    }

    /// Full-dataset perplexity with explicit parameter literals.
    pub fn perplexity(&self, params: &[xla::Literal], data: &Dataset) -> Result<EvalResult> {
        let exe = self.engine.load("fwd_eval")?;
        let mut total_nll = 0.0f64;
        let mut total_tok = 0usize;
        let mut batches = 0usize;
        for batch in data.iter() {
            // Params by reference — converted once by the caller, reused
            // for every batch (§Perf: was 2 host copies per param/batch).
            let tok_lit = tokens_to_literal(&batch.inputs, batch.batch, batch.seq)?;
            let tgt_lit = tokens_to_literal(&batch.targets, batch.batch, batch.seq)?;
            let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(params.len() + 2);
            inputs.extend(params.iter());
            inputs.extend([&tok_lit, &tgt_lit]);
            let outs = exe.run_refs(&inputs)?;
            let nll_rows = literal_to_tensor(&outs[0])?;
            let tok_rows = literal_to_tensor(&outs[1])?;
            total_nll += nll_rows.data().iter().map(|&v| v as f64).sum::<f64>();
            total_tok += tok_rows.data().iter().map(|&v| v as f64).sum::<f64>() as usize;
            batches += 1;
        }
        anyhow::ensure!(batches > 0, "eval dataset produced no batches");
        let nll_per_token = total_nll / total_tok.max(1) as f64;
        Ok(EvalResult { perplexity: nll_per_token.exp(), nll_per_token, tokens: total_tok, batches })
    }

    /// Convenience: perplexity straight from a checkpoint.
    pub fn perplexity_of(&self, ck: &Checkpoint, data: &Dataset) -> Result<EvalResult> {
        let params = self.params_from_checkpoint(ck)?;
        self.perplexity(&params, data)
    }

    /// Parameter literals straight from a `.swsc` container.
    ///
    /// The `fwd_eval` executable's contract is dense parameter literals,
    /// so compressed entries are restored host-side here (`W' + A·B`,
    /// via [`restore_param_tensors`]). The compressed-domain serving
    /// surface — matmuls with no reconstruction, behind the `InferMode`
    /// flag — lives in [`crate::infer`] and
    /// `coordinator::EvalService::start_with_swsc`; its accelerator-side
    /// analog is the L1 `decode_matmul` kernel.
    pub fn params_from_swsc(&self, file: &SwscFile) -> Result<Vec<xla::Literal>> {
        restore_param_tensors(file, &self.cfg)?.iter().map(tensor_to_literal).collect()
    }

    /// Convenience: perplexity straight from a `.swsc` container.
    pub fn perplexity_of_swsc(&self, file: &SwscFile, data: &Dataset) -> Result<EvalResult> {
        let params = self.params_from_swsc(file)?;
        self.perplexity(&params, data)
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::model::init_params;

    /// Compress a tiny model's fresh init into a servable container: 2-D
    /// params with ≥ 16 columns become compressed entries, the rest dense.
    fn tiny_file(cfg: &ModelConfig, seed: u64) -> SwscFile {
        let ck = init_params(cfg, seed);
        let mut file = SwscFile::new();
        for spec in param_specs(cfg) {
            let t = ck.get(&spec.name).unwrap().clone();
            if spec.shape.len() == 2 && spec.shape[1] >= 16 {
                file.compressed
                    .insert(spec.name.clone(), compress_matrix(&t, &SwscConfig::new(8, 2)));
            } else {
                file.dense.insert(spec.name.clone(), t);
            }
        }
        file
    }

    fn tiny_stream(cfg: &ModelConfig, windows: usize) -> Dataset {
        let len = cfg.batch * cfg.seq * windows + 1;
        let ids: Vec<i32> = (0..len).map(|i| (i * 7 % cfg.vocab) as i32).collect();
        Dataset::from_ids(ids, cfg.batch, cfg.seq)
    }

    /// Compressed-domain perplexity needs no engine, is finite, sits near
    /// ln(vocab) for a fresh init, tracks the reconstructed-dense oracle,
    /// and is bitwise thread-invariant (f32 logits are, so the f64 NLL
    /// reduction over them is too).
    #[test]
    fn compressed_perplexity_is_sane_and_thread_invariant() {
        let cfg = ModelConfig::tiny();
        let file = tiny_file(&cfg, 7);
        let data = tiny_stream(&cfg, 1);
        let serial = perplexity_swsc_compressed(
            &file,
            &cfg,
            InferMode::Compressed,
            &data,
            ExecConfig::serial(),
        )
        .unwrap();
        assert_eq!(data.num_batches(), 1);
        assert_eq!(serial.batches, 1);
        assert_eq!(serial.tokens, cfg.batch * cfg.seq);
        assert!(serial.perplexity.is_finite() && serial.perplexity > 1.0);
        let uniform = (cfg.vocab as f64).ln();
        assert!(
            (serial.nll_per_token - uniform).abs() < 1.0,
            "fresh-init nll/token {} should be near ln(vocab) = {uniform}",
            serial.nll_per_token
        );
        let par = perplexity_swsc_compressed(
            &file,
            &cfg,
            InferMode::Compressed,
            &data,
            ExecConfig::with_threads(4),
        )
        .unwrap();
        assert_eq!(serial.perplexity.to_bits(), par.perplexity.to_bits(), "thread parity");
        let reco = perplexity_swsc_compressed(
            &file,
            &cfg,
            InferMode::Reconstructed,
            &data,
            ExecConfig::serial(),
        )
        .unwrap();
        let rel = (serial.nll_per_token - reco.nll_per_token).abs() / reco.nll_per_token;
        assert!(rel < 1e-3, "compressed vs reconstructed nll/token drifted {rel}");
    }

    /// A container missing a parameter fails at build time with a named
    /// error, and an empty dataset is an explicit error not a NaN.
    #[test]
    fn compressed_perplexity_error_paths() {
        let cfg = ModelConfig::tiny();
        let mut file = tiny_file(&cfg, 8);
        let data = tiny_stream(&cfg, 1);
        let empty = Dataset::from_ids(vec![0; 4], cfg.batch, cfg.seq);
        let e = perplexity_swsc_compressed(
            &file,
            &cfg,
            InferMode::Compressed,
            &empty,
            ExecConfig::serial(),
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("no batches"), "got: {e:#}");
        file.dense.remove("final_ln.g");
        let e = perplexity_swsc_compressed(
            &file,
            &cfg,
            InferMode::Compressed,
            &data,
            ExecConfig::serial(),
        )
        .unwrap_err();
        assert!(format!("{e:#}").contains("final_ln.g"), "got: {e:#}");
    }
}
