//! Perplexity evaluation over the AOT `fwd_eval` executable.
//!
//! `fwd_eval(params..., tokens, targets)` returns per-row negative
//! log-likelihood sums and per-row token counts; perplexity is
//! `exp(Σ nll / Σ tokens)` over the eval stream — the same quantity the
//! paper reports on WikiText-2.

use crate::io::Checkpoint;
use crate::model::{param_specs, ModelConfig};
use crate::runtime::{literal_to_tensor, tensor_to_literal, tokens_to_literal, Engine};
use crate::text::Dataset;
use anyhow::{Context, Result};

/// Perplexity evaluator bound to one engine + model config.
pub struct Evaluator {
    engine: Engine,
    cfg: ModelConfig,
}

/// Result of an eval pass.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub perplexity: f64,
    pub nll_per_token: f64,
    pub tokens: usize,
    pub batches: usize,
}

impl Evaluator {
    pub fn new(engine: Engine, cfg: ModelConfig) -> Result<Evaluator> {
        engine.manifest().verify_config(&cfg)?;
        Ok(Evaluator { engine, cfg })
    }

    /// Convert a checkpoint into the canonical literal argument list.
    pub fn params_from_checkpoint(&self, ck: &Checkpoint) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::new();
        for spec in param_specs(&self.cfg) {
            let t = ck.get(&spec.name).with_context(|| format!("checkpoint missing {}", spec.name))?;
            anyhow::ensure!(
                t.shape() == &spec.shape[..],
                "param {} shape {:?} != {:?}",
                spec.name,
                t.shape(),
                spec.shape
            );
            out.push(tensor_to_literal(t)?);
        }
        Ok(out)
    }

    /// Full-dataset perplexity with explicit parameter literals.
    pub fn perplexity(&self, params: &[xla::Literal], data: &Dataset) -> Result<EvalResult> {
        let exe = self.engine.load("fwd_eval")?;
        let mut total_nll = 0.0f64;
        let mut total_tok = 0usize;
        let mut batches = 0usize;
        for batch in data.iter() {
            // Params by reference — converted once by the caller, reused
            // for every batch (§Perf: was 2 host copies per param/batch).
            let tok_lit = tokens_to_literal(&batch.inputs, batch.batch, batch.seq)?;
            let tgt_lit = tokens_to_literal(&batch.targets, batch.batch, batch.seq)?;
            let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(params.len() + 2);
            inputs.extend(params.iter());
            inputs.extend([&tok_lit, &tgt_lit]);
            let outs = exe.run_refs(&inputs)?;
            let nll_rows = literal_to_tensor(&outs[0])?;
            let tok_rows = literal_to_tensor(&outs[1])?;
            total_nll += nll_rows.data().iter().map(|&v| v as f64).sum::<f64>();
            total_tok += tok_rows.data().iter().map(|&v| v as f64).sum::<f64>() as usize;
            batches += 1;
        }
        anyhow::ensure!(batches > 0, "eval dataset produced no batches");
        let nll_per_token = total_nll / total_tok.max(1) as f64;
        Ok(EvalResult { perplexity: nll_per_token.exp(), nll_per_token, tokens: total_tok, batches })
    }

    /// Convenience: perplexity straight from a checkpoint.
    pub fn perplexity_of(&self, ck: &Checkpoint, data: &Dataset) -> Result<EvalResult> {
        let params = self.params_from_checkpoint(ck)?;
        self.perplexity(&params, data)
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}
