//! Perplexity evaluation over the AOT `fwd_eval` executable.
//!
//! `fwd_eval(params..., tokens, targets)` returns per-row negative
//! log-likelihood sums and per-row token counts; perplexity is
//! `exp(Σ nll / Σ tokens)` over the eval stream — the same quantity the
//! paper reports on WikiText-2.

use crate::io::{Checkpoint, SwscFile};
use crate::model::{param_specs, ModelConfig, ParamSpec};
use crate::runtime::{literal_to_tensor, tensor_to_literal, tokens_to_literal, Engine};
use crate::tensor::Tensor;
use crate::text::Dataset;
use anyhow::{Context, Result};

/// The one place a resolved parameter tensor is checked against its spec —
/// shared by every param source (checkpoint, `.swsc`) so the error shape
/// can never drift between surfaces.
fn ensure_spec_shape(spec: &ParamSpec, t: &Tensor) -> Result<()> {
    anyhow::ensure!(
        t.shape() == &spec.shape[..],
        "param {} shape {:?} != {:?}",
        spec.name,
        t.shape(),
        spec.shape
    );
    Ok(())
}

/// Dense parameter tensors for `cfg`, restored from a `.swsc` container in
/// canonical [`param_specs`] order with shape validation. Shared by
/// [`Evaluator::params_from_swsc`] and the serving front's PJRT path
/// (`coordinator::EvalService::start_with_swsc`).
pub fn restore_param_tensors(file: &SwscFile, cfg: &ModelConfig) -> Result<Vec<Tensor>> {
    let mut restored = file.restore_all();
    let mut out = Vec::new();
    for spec in param_specs(cfg) {
        let t = restored
            .remove(&spec.name)
            .with_context(|| format!("swsc container missing {}", spec.name))?;
        ensure_spec_shape(&spec, &t)?;
        out.push(t);
    }
    Ok(out)
}

/// Perplexity evaluator bound to one engine + model config.
pub struct Evaluator {
    engine: Engine,
    cfg: ModelConfig,
}

/// Result of an eval pass.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub perplexity: f64,
    pub nll_per_token: f64,
    pub tokens: usize,
    pub batches: usize,
}

impl Evaluator {
    pub fn new(engine: Engine, cfg: ModelConfig) -> Result<Evaluator> {
        engine.manifest().verify_config(&cfg)?;
        Ok(Evaluator { engine, cfg })
    }

    /// Convert a checkpoint into the canonical literal argument list.
    pub fn params_from_checkpoint(&self, ck: &Checkpoint) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::new();
        for spec in param_specs(&self.cfg) {
            let t = ck
                .get(&spec.name)
                .with_context(|| format!("checkpoint missing {}", spec.name))?;
            ensure_spec_shape(&spec, t)?;
            out.push(tensor_to_literal(t)?);
        }
        Ok(out)
    }

    /// Full-dataset perplexity with explicit parameter literals.
    pub fn perplexity(&self, params: &[xla::Literal], data: &Dataset) -> Result<EvalResult> {
        let exe = self.engine.load("fwd_eval")?;
        let mut total_nll = 0.0f64;
        let mut total_tok = 0usize;
        let mut batches = 0usize;
        for batch in data.iter() {
            // Params by reference — converted once by the caller, reused
            // for every batch (§Perf: was 2 host copies per param/batch).
            let tok_lit = tokens_to_literal(&batch.inputs, batch.batch, batch.seq)?;
            let tgt_lit = tokens_to_literal(&batch.targets, batch.batch, batch.seq)?;
            let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(params.len() + 2);
            inputs.extend(params.iter());
            inputs.extend([&tok_lit, &tgt_lit]);
            let outs = exe.run_refs(&inputs)?;
            let nll_rows = literal_to_tensor(&outs[0])?;
            let tok_rows = literal_to_tensor(&outs[1])?;
            total_nll += nll_rows.data().iter().map(|&v| v as f64).sum::<f64>();
            total_tok += tok_rows.data().iter().map(|&v| v as f64).sum::<f64>() as usize;
            batches += 1;
        }
        anyhow::ensure!(batches > 0, "eval dataset produced no batches");
        let nll_per_token = total_nll / total_tok.max(1) as f64;
        Ok(EvalResult { perplexity: nll_per_token.exp(), nll_per_token, tokens: total_tok, batches })
    }

    /// Convenience: perplexity straight from a checkpoint.
    pub fn perplexity_of(&self, ck: &Checkpoint, data: &Dataset) -> Result<EvalResult> {
        let params = self.params_from_checkpoint(ck)?;
        self.perplexity(&params, data)
    }

    /// Parameter literals straight from a `.swsc` container.
    ///
    /// The `fwd_eval` executable's contract is dense parameter literals,
    /// so compressed entries are restored host-side here (`W' + A·B`,
    /// via [`restore_param_tensors`]). The compressed-domain serving
    /// surface — matmuls with no reconstruction, behind the `InferMode`
    /// flag — lives in [`crate::infer`] and
    /// `coordinator::EvalService::start_with_swsc`; its accelerator-side
    /// analog is the L1 `decode_matmul` kernel.
    pub fn params_from_swsc(&self, file: &SwscFile) -> Result<Vec<xla::Literal>> {
        restore_param_tensors(file, &self.cfg)?.iter().map(tensor_to_literal).collect()
    }

    /// Convenience: perplexity straight from a `.swsc` container.
    pub fn perplexity_of_swsc(&self, file: &SwscFile, data: &Dataset) -> Result<EvalResult> {
        let params = self.params_from_swsc(file)?;
        self.perplexity(&params, data)
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}
