//! Centroid seeding: uniform-random and k-means++.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Seeding strategy for K-Means.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitMethod {
    /// Pick `k` distinct channels uniformly at random.
    Random,
    /// k-means++ (Arthur & Vassilvitskii): D²-weighted sequential seeding.
    KMeansPlusPlus,
}

/// `points` is row-major (one point per row, n × m). Returns k × m centroids.
pub fn init_random(points: &Tensor, k: usize, rng: &mut Rng) -> Tensor {
    let n = points.rows();
    let m = points.cols();
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut out = Tensor::zeros(&[k, m]);
    for c in 0..k {
        out.row_mut(c).copy_from_slice(points.row(idx[c % n]));
    }
    out
}

/// k-means++ seeding: first centroid uniform, then each next centroid drawn
/// with probability proportional to its squared distance to the nearest
/// centroid chosen so far. Keeps a running `d2` array so the whole thing is
/// O(n·k·m).
pub fn init_kmeans_pp(points: &Tensor, k: usize, rng: &mut Rng) -> Tensor {
    let n = points.rows();
    let m = points.cols();
    let mut out = Tensor::zeros(&[k, m]);

    let first = rng.below(n);
    out.row_mut(0).copy_from_slice(points.row(first));

    let mut d2: Vec<f64> = (0..n).map(|j| Tensor::dist2(points.row(j), out.row(0))).collect();

    for c in 1..k {
        let pick = rng.weighted(&d2);
        // Copy via split to satisfy the borrow checker.
        let (src_is_done, pick_row): (bool, Vec<f32>) = (false, points.row(pick).to_vec());
        let _ = src_is_done;
        out.row_mut(c).copy_from_slice(&pick_row);
        // Update running nearest-distance.
        for j in 0..n {
            let d = Tensor::dist2(points.row(j), out.row(c));
            if d < d2[j] {
                d2[j] = d;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_init_rows_are_input_points() {
        let mut rng = Rng::new(31);
        let pts = Tensor::randn(&[10, 4], &mut rng);
        let cen = init_random(&pts, 3, &mut rng);
        for c in 0..3 {
            assert!((0..10).any(|j| pts.row(j) == cen.row(c)));
        }
    }

    #[test]
    fn kpp_spreads_centroids() {
        // Two tight far-apart blobs; k-means++ must pick one seed from each.
        let mut rng = Rng::new(32);
        let mut pts = Tensor::zeros(&[20, 2]);
        for j in 0..20 {
            let base = if j < 10 { 0.0 } else { 100.0 };
            pts.row_mut(j)
                .copy_from_slice(&[base + rng.normal_f32(0.0, 0.1), base + rng.normal_f32(0.0, 0.1)]);
        }
        let cen = init_kmeans_pp(&pts, 2, &mut rng);
        let far = Tensor::dist2(cen.row(0), cen.row(1));
        assert!(far > 1_000.0, "seeds not spread: d2 = {far}");
    }

    #[test]
    fn kpp_handles_duplicate_points() {
        let mut rng = Rng::new(33);
        let pts = Tensor::full(&[8, 3], 1.0);
        let cen = init_kmeans_pp(&pts, 4, &mut rng);
        assert_eq!(cen.shape(), &[4, 3]);
        // All distances zero → weighted() falls back to uniform; no panic.
        for c in 0..4 {
            assert_eq!(cen.row(c), &[1.0, 1.0, 1.0]);
        }
    }
}
