//! Lloyd iterations: assign → update, with empty-cluster repair.
//!
//! Both steps run on the deterministic executor: points are cut into fixed
//! [`POINT_CHUNK`]-sized chunks (independent of thread count), each chunk
//! produces labels plus partial sums, and partials are reduced in chunk
//! order — so labels, inertia, and centroids are bit-identical at any
//! thread count.
//!
//! The assignment step has two implementations with bitwise-identical
//! output: the blocked cross-term path ([`assign_blocked_with`], default —
//! per-chunk GEMM blocks fused with the argmin, sized for 11008-channel
//! MLP matrices) and the un-blocked full-GEMM reference
//! ([`assign_gemm_with`], oracle/baseline).

use crate::exec::{self, ExecConfig};
use crate::tensor::gemm::{self, GemmKernel};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Fixed chunk size (in points) for the assign/update steps. Part of the
/// numeric contract: partial inertia/centroid sums are grouped per chunk,
/// so this constant must not depend on the thread count.
pub const POINT_CHUNK: usize = 128;

/// Output of the assignment step.
#[derive(Debug, Clone)]
pub struct AssignResult {
    pub labels: Vec<u32>,
    pub inertia: f64,
    pub iterations: usize,
    /// Inertia after each Lloyd iteration (telemetry, PR 10): one entry
    /// per iteration actually run, last entry == `inertia`. A pure
    /// function of (points, seed centroids) — same determinism contract
    /// as the labels, so it is golden-testable. Empty when produced by a
    /// bare [`assign`] call.
    pub inertia_trace: Vec<f64>,
}

/// Assign every point (row of `points`) to its nearest centroid row.
///
/// Distance uses the expansion ‖x−c‖² = ‖x‖² − 2·xᵀc + ‖c‖²; the cross term
/// is a matmul, which is exactly how the L1 Pallas kernel phrases it for the
/// MXU — keeping the two implementations step-equivalent.
pub fn assign(points: &Tensor, centroids: &Tensor) -> (Vec<u32>, f64) {
    assign_with(points, centroids, exec::global())
}

/// [`assign`] with an explicit thread config. Labels are per-point
/// independent; inertia is reduced from fixed-chunk partials in chunk
/// order — bit-identical at any `exec.threads`.
///
/// Runs the blocked cross-term path ([`assign_blocked_with`]); the
/// un-blocked full-GEMM reference ([`assign_gemm_with`]) produces
/// bitwise-identical output and is kept as the test oracle and bench
/// baseline.
pub fn assign_with(points: &Tensor, centroids: &Tensor, exec: ExecConfig) -> (Vec<u32>, f64) {
    assign_blocked_with(points, centroids, exec)
}

/// Blocked cross-term assignment — the wide-matrix path.
///
/// Instead of materializing the full `n × k` cross-term product (a real
/// allocation at 11008-channel MLP widths) and re-walking it in a second
/// pass, each fixed [`POINT_CHUNK`]-point chunk computes its own
/// `chunk × k` cross-term block and fuses the argmin over centroids while
/// the block is hot, using precomputed ‖c‖². The per-chunk tiles run on the
/// same shared GEMM engine as `Tensor::matmul` (packed register-tiled by
/// default, with the centroid panels packed **once** per assign call and
/// reused by every chunk; the old cache-blocked kernel under
/// [`GemmKernel::Blocked`]). Every kernel accumulates each cross term in a
/// single f32 register over increasing dims, so every label, inertia bit,
/// and downstream centroid is bitwise identical to [`assign_gemm_with`] at
/// any thread count and under either kernel.
pub fn assign_blocked_with(points: &Tensor, centroids: &Tensor, exec: ExecConfig) -> (Vec<u32>, f64) {
    let n = points.rows();
    let k = centroids.rows();
    let m = points.cols();
    debug_assert_eq!(m, centroids.cols());

    let cnorm: Vec<f64> = (0..k).map(|c| Tensor::dot(centroids.row(c), centroids.row(c))).collect();
    // Same right-hand operand as the GEMM path: centroids transposed once
    // (m × k — small next to the points), then packed once into the shared
    // engine's column panels so chunks don't re-pack it.
    let cent_t = centroids.transpose_with(exec);
    let packed = match gemm::kernel() {
        GemmKernel::Packed => Some(gemm::pack_b(cent_t.data(), m, k, exec)),
        GemmKernel::Blocked => None,
    };

    let parts = exec::map_chunks(exec, n, POINT_CHUNK, |range| {
        let rows = range.len();
        // cross[jr][c] = points[range.start + jr] · centroids[c]
        let mut cross = vec![0.0f32; rows * k];
        match &packed {
            Some(pb) => gemm::gemm_rows(
                gemm::ASrc::Rows { data: points.data(), k: m },
                range.start,
                rows,
                pb,
                &mut cross,
                false,
            ),
            None => crate::tensor::matmul_band(points.data(), cent_t.data(), m, k, range.start, &mut cross),
        }

        let mut labels = Vec::with_capacity(rows);
        let mut partial = 0.0f64;
        for (jr, j) in range.enumerate() {
            let pnorm = Tensor::dot(points.row(j), points.row(j));
            let crow = &cross[jr * k..(jr + 1) * k];
            let mut best_c = 0usize;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let d = pnorm - 2.0 * crow[c] as f64 + cnorm[c];
                if d < best_d {
                    best_d = d;
                    best_c = c;
                }
            }
            labels.push(best_c as u32);
            partial += best_d.max(0.0);
        }
        (labels, partial)
    });

    reduce_assign_parts(n, parts)
}

/// Un-blocked reference assignment: one full `n × k` cross-term GEMM, then
/// a label pass. Kept public as the oracle for the blocked-vs-naive
/// property test and the bench baseline; output is bitwise identical to
/// [`assign_blocked_with`].
pub fn assign_gemm_with(points: &Tensor, centroids: &Tensor, exec: ExecConfig) -> (Vec<u32>, f64) {
    let n = points.rows();
    let k = centroids.rows();
    debug_assert_eq!(points.cols(), centroids.cols());

    let cnorm: Vec<f64> = (0..k).map(|c| Tensor::dot(centroids.row(c), centroids.row(c))).collect();
    // cross[j][c] = points[j] · centroids[c]   (n×m · m×k)
    let cross = points.matmul_with(&centroids.transpose_with(exec), exec);

    let parts = exec::map_chunks(exec, n, POINT_CHUNK, |range| {
        let mut labels = Vec::with_capacity(range.len());
        let mut partial = 0.0f64;
        for j in range {
            let pnorm = Tensor::dot(points.row(j), points.row(j));
            let mut best_c = 0usize;
            let mut best_d = f64::INFINITY;
            let crow = cross.row(j);
            for c in 0..k {
                let d = pnorm - 2.0 * crow[c] as f64 + cnorm[c];
                if d < best_d {
                    best_d = d;
                    best_c = c;
                }
            }
            labels.push(best_c as u32);
            partial += best_d.max(0.0);
        }
        (labels, partial)
    });

    reduce_assign_parts(n, parts)
}

/// Fold per-chunk (labels, inertia) partials in chunk order — shared by
/// both assign paths so the reduction order is identical by construction.
fn reduce_assign_parts(n: usize, parts: Vec<(Vec<u32>, f64)>) -> (Vec<u32>, f64) {
    let mut labels = Vec::with_capacity(n);
    let mut inertia = 0.0f64;
    for (chunk_labels, partial) in parts {
        labels.extend_from_slice(&chunk_labels);
        inertia += partial;
    }
    (labels, inertia)
}

/// Recompute centroids as the mean of their assigned points.
/// Returns the per-cluster counts. Empty clusters keep their old position
/// (repair happens in [`lloyd`]).
pub fn update(points: &Tensor, labels: &[u32], centroids: &mut Tensor) -> Vec<usize> {
    update_with(points, labels, centroids, exec::global())
}

/// [`update`] with an explicit thread config. Each fixed chunk of points
/// accumulates its own `k × m` partial sums; partials are folded in chunk
/// order, so the means are bit-identical at any `exec.threads`.
pub fn update_with(
    points: &Tensor,
    labels: &[u32],
    centroids: &mut Tensor,
    exec: ExecConfig,
) -> Vec<usize> {
    let (k, m) = (centroids.rows(), centroids.cols());
    let mut counts = vec![0usize; k];
    let mut sums = vec![0.0f64; k * m];
    // Bounded-memory reduction: each chunk's k×m partial would be gigabytes
    // if all ⌈n/POINT_CHUNK⌉ of them were materialized on very wide
    // matrices; fold_chunks keeps at most `threads` alive while preserving
    // the fixed chunk layout and fold order.
    exec::fold_chunks(
        exec,
        labels.len(),
        POINT_CHUNK,
        |range| {
            let mut counts = vec![0usize; k];
            let mut sums = vec![0.0f64; k * m];
            for j in range {
                let c = labels[j] as usize;
                counts[c] += 1;
                let row = points.row(j);
                let acc = &mut sums[c * m..(c + 1) * m];
                for (a, &v) in acc.iter_mut().zip(row) {
                    *a += v as f64;
                }
            }
            (counts, sums)
        },
        |(chunk_counts, chunk_sums)| {
            for (c, &cc) in chunk_counts.iter().enumerate() {
                counts[c] += cc;
            }
            for (a, &v) in sums.iter_mut().zip(&chunk_sums) {
                *a += v;
            }
        },
    );
    for c in 0..k {
        if counts[c] == 0 {
            continue;
        }
        let inv = 1.0 / counts[c] as f64;
        let dst = centroids.row_mut(c);
        let src = &sums[c * m..(c + 1) * m];
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = (s * inv) as f32;
        }
    }
    counts
}

/// Full Lloyd loop. `centroids` is mutated in place (k × m, row per
/// centroid). Empty clusters are re-seeded at the point farthest from its
/// centroid — the classic repair that keeps k live clusters.
pub fn lloyd(
    points: &Tensor,
    centroids: &mut Tensor,
    max_iters: usize,
    tol: f64,
    rng: &mut Rng,
) -> AssignResult {
    lloyd_with(points, centroids, max_iters, tol, rng, exec::global())
}

/// [`lloyd`] with an explicit thread config (bit-identical at any
/// `exec.threads`, like every `_with` variant).
pub fn lloyd_with(
    points: &Tensor,
    centroids: &mut Tensor,
    max_iters: usize,
    tol: f64,
    rng: &mut Rng,
    exec: ExecConfig,
) -> AssignResult {
    let mut labels = vec![0u32; points.rows()];
    let mut inertia = f64::INFINITY;
    let mut iterations = 0;
    let mut inertia_trace = Vec::new();

    for it in 0..max_iters.max(1) {
        iterations = it + 1;
        let (new_labels, new_inertia) = assign_with(points, centroids, exec);
        labels = new_labels;
        inertia = new_inertia;
        inertia_trace.push(inertia);

        let before = centroids.clone();
        let counts = update_with(points, &labels, centroids, exec);

        // Empty-cluster repair: move dead centroids onto the worst-served
        // points so no representative vector is wasted.
        if counts.iter().any(|&c| c == 0) {
            repair_empty(points, &labels, centroids, &counts, rng);
        }

        let shift = centroids.sub(&before).fro_norm();
        if shift < tol {
            // Re-assign once more so labels match the final centroids.
            let (fin_labels, fin_inertia) = assign_with(points, centroids, exec);
            labels = fin_labels;
            inertia = fin_inertia;
            // The final assignment supersedes this iteration's entry.
            *inertia_trace.last_mut().unwrap() = fin_inertia;
            break;
        }
    }

    AssignResult { labels, inertia, iterations, inertia_trace }
}

fn repair_empty(
    points: &Tensor,
    labels: &[u32],
    centroids: &mut Tensor,
    counts: &[usize],
    rng: &mut Rng,
) {
    // Rank points by distance to their assigned centroid, descending.
    let mut dists: Vec<(usize, f64)> = labels
        .iter()
        .enumerate()
        .map(|(j, &lab)| (j, Tensor::dist2(points.row(j), centroids.row(lab as usize))))
        .collect();
    dists.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    let mut next = 0usize;
    for c in 0..counts.len() {
        if counts[c] > 0 {
            continue;
        }
        let j = if next < dists.len() { dists[next].0 } else { rng.below(points.rows()) };
        next += 1;
        let row: Vec<f32> = points.row(j).to_vec();
        centroids.row_mut(c).copy_from_slice(&row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_picks_nearest() {
        let pts = Tensor::from_vec(&[3, 1], vec![0.0, 0.9, 10.0]);
        let cen = Tensor::from_vec(&[2, 1], vec![0.0, 10.0]);
        let (labels, inertia) = assign(&pts, &cen);
        assert_eq!(labels, vec![0, 0, 1]);
        assert!((inertia - 0.81).abs() < 1e-6);
    }

    #[test]
    fn update_computes_means() {
        let pts = Tensor::from_vec(&[4, 1], vec![0.0, 2.0, 10.0, 14.0]);
        let mut cen = Tensor::from_vec(&[2, 1], vec![0.0, 10.0]);
        let counts = update(&pts, &[0, 0, 1, 1], &mut cen);
        assert_eq!(counts, vec![2, 2]);
        assert_eq!(cen.data(), &[1.0, 12.0]);
    }

    #[test]
    fn assign_update_bitwise_parity_across_threads() {
        let mut rng = Rng::new(44);
        // > 2 chunks of POINT_CHUNK so the reduction actually crosses chunks.
        let pts = Tensor::randn(&[3 * super::POINT_CHUNK + 17, 9], &mut rng);
        let cen0 = Tensor::randn(&[7, 9], &mut rng);
        let bits = |x: &Tensor| x.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        let (base_labels, base_inertia) = assign_with(&pts, &cen0, ExecConfig::serial());
        let mut base_cen = cen0.clone();
        let base_counts = update_with(&pts, &base_labels, &mut base_cen, ExecConfig::serial());
        for threads in [2, 4, 8] {
            let cfg = ExecConfig::with_threads(threads);
            let (labels, inertia) = assign_with(&pts, &cen0, cfg);
            assert_eq!(labels, base_labels, "labels, {threads} threads");
            assert_eq!(inertia.to_bits(), base_inertia.to_bits(), "inertia, {threads} threads");
            let mut cen = cen0.clone();
            let counts = update_with(&pts, &labels, &mut cen, cfg);
            assert_eq!(counts, base_counts, "counts, {threads} threads");
            assert_eq!(bits(&cen), bits(&base_cen), "centroids, {threads} threads");
        }
    }

    #[test]
    fn blocked_and_gemm_assign_bitwise_identical() {
        let mut rng = Rng::new(45);
        // Ragged point count across several chunks; k not a tile multiple.
        let pts = Tensor::randn(&[5 * super::POINT_CHUNK + 31, 11], &mut rng);
        let cen = Tensor::randn(&[9, 11], &mut rng);
        for threads in [1, 2, 4, 8] {
            let cfg = ExecConfig::with_threads(threads);
            let (bl, bi) = assign_blocked_with(&pts, &cen, cfg);
            let (gl, gi) = assign_gemm_with(&pts, &cen, cfg);
            assert_eq!(bl, gl, "labels, {threads} threads");
            assert_eq!(bi.to_bits(), gi.to_bits(), "inertia, {threads} threads");
        }
    }

    #[test]
    fn lloyd_converges_on_two_blobs() {
        let mut rng = Rng::new(41);
        let mut pts = Tensor::zeros(&[40, 2]);
        for j in 0..40 {
            let base = if j < 20 { 0.0 } else { 50.0 };
            pts.row_mut(j)
                .copy_from_slice(&[base + rng.normal_f32(0.0, 0.5), base + rng.normal_f32(0.0, 0.5)]);
        }
        let mut cen = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 2.0, 2.0]);
        let res = lloyd(&pts, &mut cen, 100, 1e-9, &mut rng);
        // One centroid near (0,0), one near (50,50).
        let near0 = (0..2).any(|c| Tensor::dist2(cen.row(c), &[0.0, 0.0]) < 5.0);
        let near50 = (0..2).any(|c| Tensor::dist2(cen.row(c), &[50.0, 50.0]) < 5.0);
        assert!(near0 && near50, "centroids: {:?}", cen.data());
        assert!(res.inertia < 40.0);
        // Telemetry trace: one entry per iteration, ending at the final
        // inertia, non-increasing (repair can only help on these blobs).
        assert_eq!(res.inertia_trace.len(), res.iterations);
        assert_eq!(res.inertia_trace.last().copied().unwrap().to_bits(), res.inertia.to_bits());
        for w in res.inertia_trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "inertia trace went up: {:?}", res.inertia_trace);
        }
    }

    #[test]
    fn empty_cluster_gets_repaired() {
        // Both seeds in the same spot; second cluster would stay empty
        // without repair.
        let pts = Tensor::from_vec(&[4, 1], vec![0.0, 0.1, 9.9, 10.0]);
        let mut cen = Tensor::from_vec(&[2, 1], vec![0.0, 0.0]);
        let mut rng = Rng::new(42);
        let res = lloyd_with(&pts, &mut cen, 20, 1e-9, &mut rng, ExecConfig::serial());
        let mut seen: Vec<u32> = res.labels.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 2, "repair failed; labels {:?}", res.labels);
    }

    #[test]
    fn inertia_non_increasing_over_iters() {
        let mut rng = Rng::new(43);
        let pts = Tensor::randn(&[60, 5], &mut rng);
        let mut cen = super::super::init::init_kmeans_pp(&pts, 6, &mut rng);
        let mut last = f64::INFINITY;
        for _ in 0..10 {
            let (labels, inertia) = assign(&pts, &cen);
            assert!(inertia <= last + 1e-6, "inertia went up: {inertia} > {last}");
            last = inertia;
            update(&pts, &labels, &mut cen);
        }
    }
}
