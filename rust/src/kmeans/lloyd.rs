//! Lloyd iterations: assign → update, with empty-cluster repair.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Output of the assignment step.
#[derive(Debug, Clone)]
pub struct AssignResult {
    pub labels: Vec<u32>,
    pub inertia: f64,
    pub iterations: usize,
}

/// Assign every point (row of `points`) to its nearest centroid row.
///
/// Distance uses the expansion ‖x−c‖² = ‖x‖² − 2·xᵀc + ‖c‖²; the cross term
/// is a matmul, which is exactly how the L1 Pallas kernel phrases it for the
/// MXU — keeping the two implementations step-equivalent.
pub fn assign(points: &Tensor, centroids: &Tensor) -> (Vec<u32>, f64) {
    let n = points.rows();
    let k = centroids.rows();
    debug_assert_eq!(points.cols(), centroids.cols());

    let cnorm: Vec<f64> = (0..k).map(|c| Tensor::dot(centroids.row(c), centroids.row(c))).collect();
    // cross[j][c] = points[j] · centroids[c]   (n×m · m×k)
    let cross = points.matmul(&centroids.transpose());

    let mut labels = vec![0u32; n];
    let mut inertia = 0.0f64;
    for j in 0..n {
        let pnorm = Tensor::dot(points.row(j), points.row(j));
        let mut best_c = 0usize;
        let mut best_d = f64::INFINITY;
        let crow = cross.row(j);
        for c in 0..k {
            let d = pnorm - 2.0 * crow[c] as f64 + cnorm[c];
            if d < best_d {
                best_d = d;
                best_c = c;
            }
        }
        labels[j] = best_c as u32;
        inertia += best_d.max(0.0);
    }
    (labels, inertia)
}

/// Recompute centroids as the mean of their assigned points.
/// Returns the per-cluster counts. Empty clusters keep their old position
/// (repair happens in [`lloyd`]).
pub fn update(points: &Tensor, labels: &[u32], centroids: &mut Tensor) -> Vec<usize> {
    let (k, m) = (centroids.rows(), centroids.cols());
    let mut counts = vec![0usize; k];
    let mut sums = vec![0.0f64; k * m];
    for (j, &lab) in labels.iter().enumerate() {
        let c = lab as usize;
        counts[c] += 1;
        let row = points.row(j);
        let acc = &mut sums[c * m..(c + 1) * m];
        for (a, &v) in acc.iter_mut().zip(row) {
            *a += v as f64;
        }
    }
    for c in 0..k {
        if counts[c] == 0 {
            continue;
        }
        let inv = 1.0 / counts[c] as f64;
        let dst = centroids.row_mut(c);
        let src = &sums[c * m..(c + 1) * m];
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = (s * inv) as f32;
        }
    }
    counts
}

/// Full Lloyd loop. `centroids` is mutated in place (k × m, row per
/// centroid). Empty clusters are re-seeded at the point farthest from its
/// centroid — the classic repair that keeps k live clusters.
pub fn lloyd(
    points: &Tensor,
    centroids: &mut Tensor,
    max_iters: usize,
    tol: f64,
    rng: &mut Rng,
) -> AssignResult {
    let mut labels = vec![0u32; points.rows()];
    let mut inertia = f64::INFINITY;
    let mut iterations = 0;

    for it in 0..max_iters.max(1) {
        iterations = it + 1;
        let (new_labels, new_inertia) = assign(points, centroids);
        labels = new_labels;
        inertia = new_inertia;

        let before = centroids.clone();
        let counts = update(points, &labels, centroids);

        // Empty-cluster repair: move dead centroids onto the worst-served
        // points so no representative vector is wasted.
        if counts.iter().any(|&c| c == 0) {
            repair_empty(points, &labels, centroids, &counts, rng);
        }

        let shift = centroids.sub(&before).fro_norm();
        if shift < tol {
            // Re-assign once more so labels match the final centroids.
            let (fin_labels, fin_inertia) = assign(points, centroids);
            labels = fin_labels;
            inertia = fin_inertia;
            break;
        }
    }

    AssignResult { labels, inertia, iterations }
}

fn repair_empty(
    points: &Tensor,
    labels: &[u32],
    centroids: &mut Tensor,
    counts: &[usize],
    rng: &mut Rng,
) {
    // Rank points by distance to their assigned centroid, descending.
    let mut dists: Vec<(usize, f64)> = labels
        .iter()
        .enumerate()
        .map(|(j, &lab)| (j, Tensor::dist2(points.row(j), centroids.row(lab as usize))))
        .collect();
    dists.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    let mut next = 0usize;
    for c in 0..counts.len() {
        if counts[c] > 0 {
            continue;
        }
        let j = if next < dists.len() { dists[next].0 } else { rng.below(points.rows()) };
        next += 1;
        let row: Vec<f32> = points.row(j).to_vec();
        centroids.row_mut(c).copy_from_slice(&row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_picks_nearest() {
        let pts = Tensor::from_vec(&[3, 1], vec![0.0, 0.9, 10.0]);
        let cen = Tensor::from_vec(&[2, 1], vec![0.0, 10.0]);
        let (labels, inertia) = assign(&pts, &cen);
        assert_eq!(labels, vec![0, 0, 1]);
        assert!((inertia - 0.81).abs() < 1e-6);
    }

    #[test]
    fn update_computes_means() {
        let pts = Tensor::from_vec(&[4, 1], vec![0.0, 2.0, 10.0, 14.0]);
        let mut cen = Tensor::from_vec(&[2, 1], vec![0.0, 10.0]);
        let counts = update(&pts, &[0, 0, 1, 1], &mut cen);
        assert_eq!(counts, vec![2, 2]);
        assert_eq!(cen.data(), &[1.0, 12.0]);
    }

    #[test]
    fn lloyd_converges_on_two_blobs() {
        let mut rng = Rng::new(41);
        let mut pts = Tensor::zeros(&[40, 2]);
        for j in 0..40 {
            let base = if j < 20 { 0.0 } else { 50.0 };
            pts.row_mut(j)
                .copy_from_slice(&[base + rng.normal_f32(0.0, 0.5), base + rng.normal_f32(0.0, 0.5)]);
        }
        let mut cen = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 2.0, 2.0]);
        let res = lloyd(&pts, &mut cen, 100, 1e-9, &mut rng);
        // One centroid near (0,0), one near (50,50).
        let near0 = (0..2).any(|c| Tensor::dist2(cen.row(c), &[0.0, 0.0]) < 5.0);
        let near50 = (0..2).any(|c| Tensor::dist2(cen.row(c), &[50.0, 50.0]) < 5.0);
        assert!(near0 && near50, "centroids: {:?}", cen.data());
        assert!(res.inertia < 40.0);
    }

    #[test]
    fn empty_cluster_gets_repaired() {
        // Both seeds in the same spot; second cluster would stay empty
        // without repair.
        let pts = Tensor::from_vec(&[4, 1], vec![0.0, 0.1, 9.9, 10.0]);
        let mut cen = Tensor::from_vec(&[2, 1], vec![0.0, 0.0]);
        let mut rng = Rng::new(42);
        let res = lloyd(&pts, &mut cen, 20, 1e-9, &mut rng);
        let mut seen: Vec<u32> = res.labels.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 2, "repair failed; labels {:?}", res.labels);
    }

    #[test]
    fn inertia_non_increasing_over_iters() {
        let mut rng = Rng::new(43);
        let pts = Tensor::randn(&[60, 5], &mut rng);
        let mut cen = super::super::init::init_kmeans_pp(&pts, 6, &mut rng);
        let mut last = f64::INFINITY;
        for _ in 0..10 {
            let (labels, inertia) = assign(&pts, &cen);
            assert!(inertia <= last + 1e-6, "inertia went up: {inertia} > {last}");
            last = inertia;
            update(&pts, &labels, &mut cen);
        }
    }
}
