//! Mini-batch K-Means (Sculley 2010) for very wide matrices.
//!
//! Full Lloyd over an `n = 11008`-channel MLP matrix is affordable but the
//! coordinator exposes this variant for the widest layers and for the
//! ablation bench: sample a batch of channels, assign them, and move each
//! centroid toward the batch mean with a per-centroid learning rate
//! `1/count`.
//!
//! Sampling is deterministic by construction: one value drawn from the
//! caller's rng seeds the run, and every step then draws its indices from
//! a private stream derived from `(that seed, step)`. The sampled channels
//! are a pure function of the rng state at call time plus the step number
//! — independent of thread count, and once the run seed is fixed, no step
//! can perturb another's samples. That is what lets minibatch participate
//! in the serial/parallel bit-parity property test alongside full Lloyd.

use super::lloyd::assign_with;
use crate::exec::{self, ExecConfig};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Run mini-batch k-means. `points` is n × m (row per channel); returns the
/// final centroids (k × m) plus a full-data assignment pass.
pub fn minibatch_kmeans(
    points: &Tensor,
    centroids: Tensor,
    batch: usize,
    steps: usize,
    rng: &mut Rng,
) -> (Tensor, Vec<u32>, f64) {
    let (cent, labels, inertia, _) =
        minibatch_kmeans_with(points, centroids, batch, steps, rng, exec::global());
    (cent, labels, inertia)
}

/// [`minibatch_kmeans`] with an explicit thread config. The per-batch and
/// final assignments run on the deterministic executor; the centroid drift
/// loop is inherently sequential (counts evolve sample by sample) and stays
/// serial, so results are bit-identical at any `exec.threads`.
///
/// The fourth tuple element is the telemetry trace (PR 10): the sampled
/// *batch* inertia at each step, before that step's centroid drift. It is
/// a pure function of (points, init centroids, rng state) like everything
/// else here, but — being sampled — is noisier than the final full-data
/// `inertia` and need not be monotone.
pub fn minibatch_kmeans_with(
    points: &Tensor,
    mut centroids: Tensor,
    batch: usize,
    steps: usize,
    rng: &mut Rng,
    exec: ExecConfig,
) -> (Tensor, Vec<u32>, f64, Vec<f64>) {
    let n = points.rows();
    let m = points.cols();
    let k = centroids.rows();
    let batch = batch.clamp(1, n);
    let mut counts = vec![1.0f64; k];

    // One draw from the caller's stream seeds every step (see module docs):
    // step sampling never touches `rng` again, so the index sequence is a
    // pure function of (sample_seed, step) — thread count and the assign
    // calls cannot perturb it.
    let sample_seed = rng.next_u64();

    let mut scratch = Tensor::zeros(&[batch, m]);
    let mut inertia_trace = Vec::with_capacity(steps);
    for step in 0..steps {
        // Sample this step's batch of rows from the step's private stream.
        let mut srng = step_rng(sample_seed, step as u64);
        for b in 0..batch {
            let j = srng.below(n);
            scratch.row_mut(b).copy_from_slice(points.row(j));
        }
        let (labels, batch_inertia) = assign_with(&scratch, &centroids, exec);
        inertia_trace.push(batch_inertia);
        for (b, &lab) in labels.iter().enumerate() {
            let c = lab as usize;
            counts[c] += 1.0;
            let eta = (1.0 / counts[c]) as f32;
            let dst = centroids.row_mut(c);
            let src = scratch.row(b);
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += eta * (s - *d);
            }
        }
    }

    let (labels, inertia) = assign_with(points, &centroids, exec);
    (centroids, labels, inertia, inertia_trace)
}

/// Private per-step sample stream: SplitMix-style scramble of `(seed,
/// step)` so adjacent steps decorrelate and steps could be generated in
/// any order (or in parallel) without changing the sampled indices.
fn step_rng(seed: u64, step: u64) -> Rng {
    Rng::new(seed ^ step.wrapping_add(1).wrapping_mul(0xA24B_AED4_963E_E407))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::init::init_kmeans_pp;

    #[test]
    fn minibatch_close_to_full_on_blobs() {
        let mut rng = Rng::new(51);
        let mut pts = Tensor::zeros(&[200, 3]);
        for j in 0..200 {
            let base = (j % 4) as f32 * 20.0;
            let row: Vec<f32> = (0..3).map(|_| base + rng.normal_f32(0.0, 0.3)).collect();
            pts.row_mut(j).copy_from_slice(&row);
        }
        let init = init_kmeans_pp(&pts, 4, &mut rng);
        let (_, labels, inertia) = minibatch_kmeans(&pts, init, 32, 100, &mut rng);
        // Each true blob maps to a single cluster.
        for blob in 0..4 {
            let first = labels[blob];
            for j in (blob..200).step_by(4) {
                assert_eq!(labels[j], first, "blob {blob} split");
            }
        }
        assert!(inertia < 600.0, "inertia {inertia}");
    }

    #[test]
    fn thread_count_never_changes_minibatch_output() {
        let mut rng = Rng::new(53);
        let pts = Tensor::randn(&[3 * crate::kmeans::POINT_CHUNK + 5, 7], &mut rng);
        let init = init_kmeans_pp(&pts, 5, &mut rng);
        let run = |threads: usize| {
            let mut r = Rng::new(99);
            minibatch_kmeans_with(&pts, init.clone(), 48, 25, &mut r, ExecConfig::with_threads(threads))
        };
        let (c1, l1, i1, t1) = run(1);
        assert_eq!(t1.len(), 25, "one trace entry per step");
        for threads in [2, 4, 8] {
            let (c, l, i, t) = run(threads);
            let bits = |t: &Tensor| t.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&c), bits(&c1), "centroids, {threads} threads");
            assert_eq!(l, l1, "labels, {threads} threads");
            assert_eq!(i.to_bits(), i1.to_bits(), "inertia, {threads} threads");
            let tbits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
            assert_eq!(tbits(&t), tbits(&t1), "inertia trace, {threads} threads");
        }
    }

    #[test]
    fn batch_larger_than_n_is_clamped() {
        let mut rng = Rng::new(52);
        let pts = Tensor::randn(&[10, 2], &mut rng);
        let init = init_kmeans_pp(&pts, 2, &mut rng);
        let (c, labels, _) = minibatch_kmeans(&pts, init, 1000, 5, &mut rng);
        assert_eq!(c.shape(), &[2, 2]);
        assert_eq!(labels.len(), 10);
    }
}
