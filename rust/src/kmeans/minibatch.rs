//! Mini-batch K-Means (Sculley 2010) for very wide matrices.
//!
//! Full Lloyd over an `n = 11008`-channel MLP matrix is affordable but the
//! coordinator exposes this variant for the widest layers and for the
//! ablation bench: sample a batch of channels, assign them, and move each
//! centroid toward the batch mean with a per-centroid learning rate
//! `1/count`.

use super::lloyd::assign_with;
use crate::exec::{self, ExecConfig};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Run mini-batch k-means. `points` is n × m (row per channel); returns the
/// final centroids (k × m) plus a full-data assignment pass.
pub fn minibatch_kmeans(
    points: &Tensor,
    centroids: Tensor,
    batch: usize,
    steps: usize,
    rng: &mut Rng,
) -> (Tensor, Vec<u32>, f64) {
    minibatch_kmeans_with(points, centroids, batch, steps, rng, exec::global())
}

/// [`minibatch_kmeans`] with an explicit thread config. The per-batch and
/// final assignments run on the deterministic executor; the centroid drift
/// loop is inherently sequential (counts evolve sample by sample) and stays
/// serial, so results are bit-identical at any `exec.threads`.
pub fn minibatch_kmeans_with(
    points: &Tensor,
    mut centroids: Tensor,
    batch: usize,
    steps: usize,
    rng: &mut Rng,
    exec: ExecConfig,
) -> (Tensor, Vec<u32>, f64) {
    let n = points.rows();
    let m = points.cols();
    let k = centroids.rows();
    let batch = batch.clamp(1, n);
    let mut counts = vec![1.0f64; k];

    let mut scratch = Tensor::zeros(&[batch, m]);
    for _ in 0..steps {
        // Sample a batch of rows.
        let mut picks = Vec::with_capacity(batch);
        for b in 0..batch {
            let j = rng.below(n);
            picks.push(j);
            scratch.row_mut(b).copy_from_slice(points.row(j));
        }
        let (labels, _) = assign_with(&scratch, &centroids, exec);
        for (b, &lab) in labels.iter().enumerate() {
            let c = lab as usize;
            counts[c] += 1.0;
            let eta = (1.0 / counts[c]) as f32;
            let dst = centroids.row_mut(c);
            let src = scratch.row(b);
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += eta * (s - *d);
            }
        }
    }

    let (labels, inertia) = assign_with(points, &centroids, exec);
    (centroids, labels, inertia)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::init::init_kmeans_pp;

    #[test]
    fn minibatch_close_to_full_on_blobs() {
        let mut rng = Rng::new(51);
        let mut pts = Tensor::zeros(&[200, 3]);
        for j in 0..200 {
            let base = (j % 4) as f32 * 20.0;
            let row: Vec<f32> = (0..3).map(|_| base + rng.normal_f32(0.0, 0.3)).collect();
            pts.row_mut(j).copy_from_slice(&row);
        }
        let init = init_kmeans_pp(&pts, 4, &mut rng);
        let (_, labels, inertia) = minibatch_kmeans(&pts, init, 32, 100, &mut rng);
        // Each true blob maps to a single cluster.
        for blob in 0..4 {
            let first = labels[blob];
            for j in (blob..200).step_by(4) {
                assert_eq!(labels[j], first, "blob {blob} split");
            }
        }
        assert!(inertia < 600.0, "inertia {inertia}");
    }

    #[test]
    fn batch_larger_than_n_is_clamped() {
        let mut rng = Rng::new(52);
        let pts = Tensor::randn(&[10, 2], &mut rng);
        let init = init_kmeans_pp(&pts, 2, &mut rng);
        let (c, labels, _) = minibatch_kmeans(&pts, init, 1000, 5, &mut rng);
        assert_eq!(c.shape(), &[2, 2]);
        assert_eq!(labels.len(), 10);
    }
}
