//! K-Means clustering over weight channels.
//!
//! The paper clusters the *columns* ("channels") of a weight matrix and
//! replaces each cluster by a representative vector. This module is the L3
//! CPU implementation: k-means++ (or random) init, Lloyd iterations with
//! empty-cluster repair, an optional mini-batch variant for very wide
//! matrices, and both mean and medoid representatives (ablation §5).
//!
//! The L1 Pallas kernel (`python/compile/kernels/kmeans.py`) implements the
//! same assignment/update steps for the accelerated path; the integration
//! tests check both agree.

mod init;
mod lloyd;
mod minibatch;

pub use init::{init_kmeans_pp, init_random, InitMethod};
pub use lloyd::{
    assign, assign_blocked_with, assign_gemm_with, assign_with, lloyd, lloyd_with, update,
    update_with, AssignResult, POINT_CHUNK,
};
pub use minibatch::{minibatch_kmeans, minibatch_kmeans_with};

use crate::exec::{self, ExecConfig};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Which vector represents a cluster (paper uses the mean; medoid is our
/// ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Representative {
    Mean,
    Medoid,
}

/// Which clustering algorithm runs under [`cluster_channels`].
///
/// Both are deterministic given the seed and bit-identical at any thread
/// count; the planner (`compress::plan`) routes very wide matrices (the
/// 11008-channel MLP regime) through [`KMeansMethod::Minibatch`], where
/// full Lloyd's per-iteration `O(n·k·m)` assignment dominates compression
/// wall-clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KMeansMethod {
    /// Full Lloyd iterations over every channel (default).
    Lloyd,
    /// Mini-batch k-means (Sculley 2010): `steps` steps over sampled
    /// batches of `batch` channels, then one full assignment pass.
    Minibatch { batch: usize, steps: usize },
}

/// K-Means configuration.
#[derive(Debug, Clone)]
pub struct KMeansConfig {
    /// Number of clusters `k`.
    pub k: usize,
    /// Maximum Lloyd iterations.
    pub max_iters: usize,
    /// Stop when total centroid movement (Frobenius) falls below this.
    pub tol: f64,
    /// Seeding strategy.
    pub init: InitMethod,
    /// Cluster representative.
    pub representative: Representative,
    /// Lloyd vs mini-batch (see [`KMeansMethod`]).
    pub method: KMeansMethod,
    /// RNG seed (clustering is deterministic given the seed).
    pub seed: u64,
    /// Thread config for the assign/update steps. Results are bit-identical
    /// at any thread count (deterministic chunked scheduling in [`exec`]).
    pub exec: ExecConfig,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        KMeansConfig {
            k: 16,
            max_iters: 50,
            tol: 1e-6,
            init: InitMethod::KMeansPlusPlus,
            representative: Representative::Mean,
            method: KMeansMethod::Lloyd,
            seed: 0,
            exec: exec::global(),
        }
    }
}

/// Result of clustering the channels (columns) of a matrix.
#[derive(Debug, Clone)]
pub struct KMeansResult {
    /// `m × k` matrix whose columns are the representative vectors.
    pub centroids: Tensor,
    /// For each of the `n` input channels, the cluster it belongs to.
    pub labels: Vec<u32>,
    /// Final within-cluster sum of squared distances.
    pub inertia: f64,
    /// Lloyd iterations actually run.
    pub iterations: usize,
    /// Inertia after each iteration (telemetry, PR 10). For full Lloyd
    /// the last entry equals `inertia`; for mini-batch the entries are
    /// per-step *batch* inertias (sampled, so noisier than the final
    /// full-assignment `inertia`). Deterministic given the seed.
    pub inertia_trace: Vec<f64>,
}

impl KMeansResult {
    /// Reconstruct the approximation `W'`: every channel replaced by its
    /// cluster representative.
    pub fn reconstruct(&self) -> Tensor {
        gather_representatives(&self.centroids, &self.labels)
    }
}

/// Gather the shared-weight approximation `W'`: channel `j` of the result
/// is column `labels[j]` of `centroids` (`m × k`, representatives as
/// columns). Row-major: per output row the centroid row is one contiguous
/// `k`-slice and every write is unit-stride (the pre-PR-3 loops walked
/// column-by-column through `at_mut`, striding `n` apart per element).
/// Shared by [`KMeansResult::reconstruct`] and the compressed-matrix
/// reconstruction in `compress::swsc`.
pub(crate) fn gather_representatives(centroids: &Tensor, labels: &[u32]) -> Tensor {
    let (m, k) = (centroids.rows(), centroids.cols());
    let n = labels.len();
    let mut out = Tensor::zeros(&[m, n]);
    let cent = centroids.data();
    let data = out.data_mut();
    for i in 0..m {
        let crow = &cent[i * k..(i + 1) * k];
        let orow = &mut data[i * n..(i + 1) * n];
        for (o, &lab) in orow.iter_mut().zip(labels) {
            *o = crow[lab as usize];
        }
    }
    out
}

/// Cluster the channels (columns) of `w` into `cfg.k` clusters.
///
/// `w` is `m × n`; channels are the `n` columns, each a vector in `R^m`.
pub fn cluster_channels(w: &Tensor, cfg: &KMeansConfig) -> KMeansResult {
    let n = w.cols();
    let k = cfg.k.min(n).max(1);
    let mut rng = Rng::new(cfg.seed);

    // Work in channel-major layout: row i = channel i (n × m). A transposed
    // copy makes every distance computation contiguous.
    let channels = w.transpose_with(cfg.exec);

    let mut centroids_rows = match cfg.init {
        InitMethod::Random => init_random(&channels, k, &mut rng),
        InitMethod::KMeansPlusPlus => init_kmeans_pp(&channels, k, &mut rng),
    };

    let res = match cfg.method {
        KMeansMethod::Lloyd => {
            lloyd_with(&channels, &mut centroids_rows, cfg.max_iters, cfg.tol, &mut rng, cfg.exec)
        }
        KMeansMethod::Minibatch { batch, steps } => {
            let (cent, labels, inertia, inertia_trace) = minibatch_kmeans_with(
                &channels,
                centroids_rows,
                batch,
                steps,
                &mut rng,
                cfg.exec,
            );
            centroids_rows = cent;
            AssignResult { labels, inertia, iterations: steps, inertia_trace }
        }
    };

    let centroids_rows = match cfg.representative {
        Representative::Mean => centroids_rows,
        Representative::Medoid => to_medoids(&channels, &centroids_rows, &res.labels),
    };

    // Back to the paper's orientation: centroids as columns (m × k).
    KMeansResult {
        centroids: centroids_rows.transpose_with(cfg.exec),
        labels: res.labels,
        inertia: res.inertia,
        iterations: res.iterations,
        inertia_trace: res.inertia_trace,
    }
}

/// Replace each mean centroid by the in-cluster channel closest to it.
fn to_medoids(channels: &Tensor, centroids: &Tensor, labels: &[u32]) -> Tensor {
    let k = centroids.rows();
    let mut best: Vec<(f64, Option<usize>)> = vec![(f64::INFINITY, None); k];
    for (j, &lab) in labels.iter().enumerate() {
        let d = Tensor::dist2(channels.row(j), centroids.row(lab as usize));
        if d < best[lab as usize].0 {
            best[lab as usize] = (d, Some(j));
        }
    }
    let mut out = centroids.clone();
    for (c, (_, j)) in best.iter().enumerate() {
        if let Some(j) = j {
            out.row_mut(c).copy_from_slice(channels.row(*j));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    /// Build a matrix whose channels form `k` well-separated groups.
    fn grouped_matrix(m: usize, n: usize, k: usize, seed: u64) -> (Tensor, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut w = Tensor::zeros(&[m, n]);
        let mut truth = Vec::with_capacity(n);
        let centers: Vec<Vec<f32>> =
            (0..k).map(|c| (0..m).map(|_| rng.normal_f32(10.0 * c as f32, 1.0)).collect()).collect();
        for j in 0..n {
            let c = j % k;
            truth.push(c);
            let col: Vec<f32> = centers[c].iter().map(|&v| v + rng.normal_f32(0.0, 0.05)).collect();
            w.set_col(j, &col);
        }
        (w, truth)
    }

    #[test]
    fn recovers_well_separated_groups() {
        let (w, truth) = grouped_matrix(16, 48, 4, 21);
        let res = cluster_channels(&w, &KMeansConfig { k: 4, ..Default::default() });
        // Labels must be a relabeling of the truth: same partition.
        let mut map = std::collections::HashMap::new();
        for (j, &lab) in res.labels.iter().enumerate() {
            let entry = map.entry(truth[j]).or_insert(lab);
            assert_eq!(*entry, lab, "channel {j} split from its true group");
        }
        assert_eq!(map.len(), 4);
        // Expected inertia ≈ n·m·σ² = 48·16·0.0025 ≈ 1.9 for correct
        // clustering; a mis-clustering would add ~10²-scale terms.
        assert!(res.inertia < 4.0, "inertia {}", res.inertia);
    }

    #[test]
    fn reconstruct_shape_and_labels_in_range() {
        let (w, _) = grouped_matrix(8, 20, 3, 22);
        let res = cluster_channels(&w, &KMeansConfig { k: 3, ..Default::default() });
        let rec = res.reconstruct();
        assert_eq!(rec.shape(), w.shape());
        assert!(res.labels.iter().all(|&l| (l as usize) < 3));
    }

    #[test]
    fn k_capped_at_n() {
        let mut rng = Rng::new(23);
        let w = Tensor::randn(&[4, 3], &mut rng);
        let res = cluster_channels(&w, &KMeansConfig { k: 100, ..Default::default() });
        assert!(res.centroids.cols() <= 3);
        // With k >= n each channel is its own cluster: perfect reconstruction.
        assert!(res.reconstruct().mse(&w) < 1e-10);
    }

    #[test]
    fn medoid_representative_is_an_actual_channel() {
        let (w, _) = grouped_matrix(8, 24, 3, 24);
        let res = cluster_channels(
            &w,
            &KMeansConfig { k: 3, representative: Representative::Medoid, ..Default::default() },
        );
        // Every centroid column equals some input channel exactly.
        for c in 0..res.centroids.cols() {
            let cen = res.centroids.col(c);
            let found = (0..w.cols()).any(|j| w.col(j) == cen);
            assert!(found, "medoid {c} is not an input channel");
        }
    }

    #[test]
    fn minibatch_method_recovers_groups_and_is_deterministic() {
        let (w, truth) = grouped_matrix(10, 120, 4, 27);
        let cfg = KMeansConfig {
            k: 4,
            method: KMeansMethod::Minibatch { batch: 32, steps: 60 },
            seed: 5,
            ..Default::default()
        };
        let res = cluster_channels(&w, &cfg);
        assert_eq!(res.labels.len(), 120);
        assert_eq!(res.iterations, 60);
        // Same partition as the truth (well-separated groups).
        let mut map = std::collections::HashMap::new();
        for (j, &lab) in res.labels.iter().enumerate() {
            let entry = map.entry(truth[j]).or_insert(lab);
            assert_eq!(*entry, lab, "channel {j} split from its true group");
        }
        // Deterministic given the seed, including across thread counts.
        let again = cluster_channels(&w, &cfg);
        assert_eq!(res.labels, again.labels);
        assert_eq!(res.centroids, again.centroids);
        let mut cfg8 = cfg.clone();
        cfg8.exec = crate::exec::ExecConfig::with_threads(8);
        let par = cluster_channels(&w, &cfg8);
        assert_eq!(res.labels, par.labels);
        assert_eq!(res.centroids, par.centroids);
    }

    #[test]
    fn deterministic_given_seed() {
        let (w, _) = grouped_matrix(8, 30, 4, 25);
        let cfg = KMeansConfig { k: 4, seed: 77, ..Default::default() };
        let a = cluster_channels(&w, &cfg);
        let b = cluster_channels(&w, &cfg);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.centroids, b.centroids);
    }

    #[test]
    fn mean_reconstruction_never_worse_than_trivial_single_cluster() {
        prop::check(
            "k>=2 inertia <= k=1 inertia",
            26,
            12,
            |r| {
                let m = 4 + r.below(12);
                let n = 8 + r.below(24);
                (Tensor::randn(&[m, n], r), 2 + r.below(6))
            },
            |(w, k)| {
                let one = cluster_channels(w, &KMeansConfig { k: 1, ..Default::default() });
                let many = cluster_channels(w, &KMeansConfig { k: *k, ..Default::default() });
                if many.inertia <= one.inertia + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("k={k}: {} > k=1: {}", many.inertia, one.inertia))
                }
            },
        );
    }
}
