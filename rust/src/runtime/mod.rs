//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The interchange format is HLO **text**, not serialized protos —
//! xla_extension 0.5.1 rejects jax ≥ 0.5 protos with 64-bit instruction
//! ids, while the text parser reassigns ids (see DESIGN.md §9 and
//! /opt/xla-example/README.md). Every executable is lowered with
//! `return_tuple=True`, so outputs come back as one tuple literal that we
//! decompose.

mod client;
pub mod convert;
mod manifest;

pub use client::{Engine, LoadedExec};
pub use convert::{literal_to_tensor, tensor_to_literal, tokens_to_literal};
pub use manifest::{ArtifactManifest, ExecutableEntry};
