//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt`, a line-oriented
//! format (the vendored crate set has no serde/JSON, and the manifest is
//! simple enough that a bespoke text format is clearer):
//!
//! ```text
//! # comments and blank lines ignored
//! preset small
//! fingerprint v512_d256_l4_h4_f1024_s128_b8
//! param embed.tok 512,256
//! ...                              # every model param, canonical order
//! executable train_step small_train_step.hlo.txt 163
//! executable fwd_eval small_fwd_eval.hlo.txt 2
//! executable kmeans_assign_k16 small_kmeans_assign_k16.hlo.txt 2
//! ```
//!
//! The `param` lines let rust assert its canonical parameter order
//! (`model::params::param_specs`) matches what python lowered — a build-time
//! contract check, not a runtime convention.

use crate::model::{param_specs, ModelConfig};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One executable artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutableEntry {
    pub name: String,
    pub file: String,
    pub n_outputs: usize,
}

/// Parsed manifest for one preset.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub preset: String,
    pub fingerprint: String,
    /// (name, shape) in the exact argument order of the executables.
    pub params: Vec<(String, Vec<usize>)>,
    pub executables: BTreeMap<String, ExecutableEntry>,
}

impl ArtifactManifest {
    /// Load and parse `<dir>/manifest.txt`, keeping only `preset` entries.
    pub fn load(dir: &Path, preset: &str) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir, preset)
    }

    /// Parse manifest text. Lines are grouped by `preset` headers; `param`,
    /// `fingerprint` and `executable` lines apply to the current preset.
    pub fn parse(text: &str, dir: &Path, want: &str) -> Result<ArtifactManifest> {
        let mut current = String::new();
        let mut fingerprint = String::new();
        let mut params = Vec::new();
        let mut executables = BTreeMap::new();
        let mut found = false;

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let kind = it.next().unwrap();
            let err = |msg: &str| anyhow::anyhow!("manifest line {}: {msg}: `{raw}`", lineno + 1);
            match kind {
                "preset" => {
                    current = it.next().ok_or_else(|| err("missing preset name"))?.to_string();
                    if current == want {
                        found = true;
                    }
                }
                "fingerprint" if current == want => {
                    fingerprint = it.next().ok_or_else(|| err("missing fingerprint"))?.to_string();
                }
                "param" if current == want => {
                    let name = it.next().ok_or_else(|| err("missing param name"))?.to_string();
                    let dims = it.next().ok_or_else(|| err("missing dims"))?;
                    let shape: Vec<usize> = dims
                        .split(',')
                        .filter(|s| !s.is_empty())
                        .map(|s| s.parse().map_err(|_| err("bad dim")))
                        .collect::<Result<_>>()?;
                    params.push((name, shape));
                }
                "executable" if current == want => {
                    let name = it.next().ok_or_else(|| err("missing exe name"))?.to_string();
                    let file = it.next().ok_or_else(|| err("missing exe file"))?.to_string();
                    let n_outputs: usize =
                        it.next().ok_or_else(|| err("missing n_outputs"))?.parse().map_err(|_| err("bad n_outputs"))?;
                    executables.insert(name.clone(), ExecutableEntry { name, file, n_outputs });
                }
                _ => {} // other presets' lines, unknown keys: ignore
            }
        }

        if !found {
            bail!("preset `{want}` not present in manifest (run `make artifacts`)");
        }
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            preset: want.to_string(),
            fingerprint,
            params,
            executables,
        })
    }

    /// Assert the manifest's parameter list matches rust's canonical order
    /// for `cfg` — the build-time contract between layers.
    pub fn verify_config(&self, cfg: &ModelConfig) -> Result<()> {
        if self.fingerprint != cfg.fingerprint() {
            bail!(
                "artifact fingerprint `{}` does not match model config `{}` — re-run `make artifacts`",
                self.fingerprint,
                cfg.fingerprint()
            );
        }
        let specs = param_specs(cfg);
        if specs.len() != self.params.len() {
            bail!("param count mismatch: manifest {} vs rust {}", self.params.len(), specs.len());
        }
        for (spec, (name, shape)) in specs.iter().zip(&self.params) {
            if &spec.name != name || &spec.shape != shape {
                bail!(
                    "param order mismatch: rust `{}` {:?} vs manifest `{}` {:?}",
                    spec.name,
                    spec.shape,
                    name,
                    shape
                );
            }
        }
        Ok(())
    }

    pub fn executable(&self, name: &str) -> Result<&ExecutableEntry> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("executable `{name}` not in manifest (have: {:?})", self.executables.keys().collect::<Vec<_>>()))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.executable(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# test manifest
preset tiny
fingerprint v256_d64_l2_h2_f128_s32_b4
param embed.tok 256,64
param embed.pos 32,64
executable fwd_eval tiny_fwd_eval.hlo.txt 2

preset small
fingerprint v512_d256_l4_h4_f1024_s128_b8
param embed.tok 512,256
executable train_step small_train_step.hlo.txt 163
";

    #[test]
    fn parses_selected_preset_only() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/a"), "tiny").unwrap();
        assert_eq!(m.fingerprint, "v256_d64_l2_h2_f128_s32_b4");
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0], ("embed.tok".to_string(), vec![256, 64]));
        assert!(m.executables.contains_key("fwd_eval"));
        assert!(!m.executables.contains_key("train_step"));
    }

    #[test]
    fn missing_preset_errors() {
        assert!(ArtifactManifest::parse(SAMPLE, Path::new("/tmp"), "big").is_err());
    }

    #[test]
    fn hlo_path_joins_dir() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/art"), "small").unwrap();
        assert_eq!(m.hlo_path("train_step").unwrap(), PathBuf::from("/art/small_train_step.hlo.txt"));
        assert!(m.hlo_path("nope").is_err());
    }

    #[test]
    fn verify_config_checks_fingerprint() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp"), "tiny").unwrap();
        let cfg = ModelConfig::small();
        assert!(m.verify_config(&cfg).is_err(), "wrong config must be rejected");
    }

    #[test]
    fn malformed_lines_error() {
        let bad = "preset x\nfingerprint f\nparam name\n";
        assert!(ArtifactManifest::parse(bad, Path::new("/tmp"), "x").is_err());
        let bad2 = "preset x\nexecutable onlyname\n";
        assert!(ArtifactManifest::parse(bad2, Path::new("/tmp"), "x").is_err());
    }
}
