//! Host tensor ⇄ XLA literal conversion.

use crate::tensor::Tensor;
use anyhow::Result;

/// f32 host tensor → XLA literal with the same shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

/// Token-id batch → i32 literal of shape `[batch, seq]`.
pub fn tokens_to_literal(ids: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
    anyhow::ensure!(ids.len() == batch * seq, "token count {} != {batch}x{seq}", ids.len());
    Ok(xla::Literal::vec1(ids).reshape(&[batch as i64, seq as i64])?)
}

/// XLA literal → f32 host tensor (converts dtype if needed).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let lit_f32 = if shape.ty() == xla::ElementType::F32 {
        None
    } else {
        Some(lit.convert(xla::PrimitiveType::F32)?)
    };
    let data = match &lit_f32 {
        Some(l) => l.to_vec::<f32>()?,
        None => lit.to_vec::<f32>()?,
    };
    Ok(Tensor::from_vec(&dims, data))
}

/// Extract a scalar f32 from a literal (any convertible dtype).
pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let t = literal_to_tensor(lit)?;
    anyhow::ensure!(t.len() == 1, "expected scalar, got shape {:?}", t.shape());
    Ok(t.data()[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tensor_literal_round_trip() {
        let mut rng = Rng::new(141);
        let t = Tensor::randn(&[3, 5], &mut rng);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn tokens_shape_checked() {
        assert!(tokens_to_literal(&[1, 2, 3], 2, 2).is_err());
        let lit = tokens_to_literal(&[1, 2, 3, 4], 2, 2).unwrap();
        assert_eq!(lit.element_count(), 4);
    }

    #[test]
    fn scalar_extraction() {
        let lit = xla::Literal::scalar(2.5f32);
        assert_eq!(literal_scalar_f32(&lit).unwrap(), 2.5);
        let t = Tensor::zeros(&[2]);
        let lit = tensor_to_literal(&t).unwrap();
        assert!(literal_scalar_f32(&lit).is_err());
    }
}
