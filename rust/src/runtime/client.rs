//! PJRT client wrapper + executable cache.

use super::manifest::ArtifactManifest;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// A compiled executable plus its manifest metadata.
pub struct LoadedExec {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    pub name: String,
    pub n_outputs: usize,
}

impl LoadedExec {
    /// Execute with literal inputs; returns the decomposed output tuple
    /// (all artifacts are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.run_refs(&inputs.iter().collect::<Vec<_>>())
    }

    /// Execute with borrowed literal inputs — the zero-copy hot path.
    ///
    /// Inputs are staged to device buffers *owned by this function* and
    /// executed via `execute_b`. This deliberately avoids the crate's
    /// literal-taking `execute`, whose C++ shim leaks every input device
    /// buffer (`buffer.release()` with no matching free — ~55 MB/step on
    /// the `small` train loop, an OOM after ~900 steps; §Perf #3 in
    /// EXPERIMENTS.md). With `execute_b` the rust `PjRtBuffer` wrappers
    /// free the inputs on drop.
    pub fn run_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut staged = Vec::with_capacity(inputs.len());
        for lit in inputs {
            staged.push(
                self.client
                    .buffer_from_host_literal(None, lit)
                    .with_context(|| format!("stage input for {}", self.name))?,
            );
        }
        let bufs = self.exe.execute_b::<xla::PjRtBuffer>(&staged).with_context(|| format!("execute {}", self.name))?;
        drop(staged); // inputs freed here — not leaked as in execute()
        let lit = bufs[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        anyhow::ensure!(
            outs.len() == self.n_outputs,
            "{}: expected {} outputs, got {}",
            self.name,
            self.n_outputs,
            outs.len()
        );
        Ok(outs)
    }
}

/// The engine: one PJRT CPU client + a lazily-populated executable cache.
/// Cloneable and thread-safe; compilation happens once per executable name.
#[derive(Clone)]
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Arc<ArtifactManifest>,
    cache: Arc<Mutex<HashMap<String, Arc<LoadedExec>>>>,
}

impl Engine {
    /// Create the engine over a parsed manifest.
    pub fn new(manifest: ArtifactManifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest: Arc::new(manifest), cache: Arc::new(Mutex::new(HashMap::new())) })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (and cache) the executable `name` from the manifest.
    pub fn load(&self, name: &str) -> Result<Arc<LoadedExec>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let entry = self.manifest.executable(name)?.clone();
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        let loaded = Arc::new(LoadedExec {
            exe,
            client: self.client.clone(),
            name: entry.name,
            n_outputs: entry.n_outputs,
        });
        self.cache.lock().unwrap().insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Number of executables currently compiled.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
