//! Dense linear algebra built from scratch.
//!
//! The paper's error-compensation step needs a truncated SVD of the
//! reconstruction error `W_err`. `jnp.linalg.svd` lowers to a LAPACK
//! custom-call on CPU that does not survive the HLO-text interchange (see
//! DESIGN.md §9), so the SVD lives here in rust:
//!
//! - [`svd_jacobi`] — exact one-sided Jacobi SVD; cubic but rock-solid,
//!   used for small matrices and as the oracle in tests.
//! - [`svd_randomized`] — Halko/Martinsson/Tropp randomized range finder +
//!   subspace iteration; the production path for `m ≥ a few hundred` when
//!   only `r ≪ min(m,n)` factors are kept.
//! - [`qr_householder`] — thin QR used by the randomized method.

mod qr;
mod svd;

pub use qr::qr_householder;
pub use svd::{svd_jacobi, svd_randomized, svd_randomized_with, truncate, Svd};
