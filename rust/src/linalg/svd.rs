//! Singular value decomposition: exact one-sided Jacobi and randomized
//! truncated SVD.

use super::qr::qr_householder;
use crate::exec::{self, ExecConfig};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// An SVD `A ≈ U · diag(s) · Vᵀ` with `U: m × r`, `s: r`, `Vt: r × n`.
#[derive(Debug, Clone)]
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub vt: Tensor,
}

impl Svd {
    /// Number of retained singular triplets.
    pub fn rank(&self) -> usize {
        self.s.len()
    }

    /// Reconstruct `U · diag(s) · Vᵀ`.
    pub fn reconstruct(&self) -> Tensor {
        let us = self.scaled_u();
        us.matmul(&self.vt)
    }

    /// `U · diag(s)` — convenient for the paper's `A = U_r Σ^{1/2}`,
    /// `B = Σ^{1/2} V_rᵀ` split (see [`Svd::split_factors`]). One row-major
    /// pass: each U row is scaled elementwise by `s` in place of the old
    /// column-by-column walk that strided `r` apart on every write.
    pub fn scaled_u(&self) -> Tensor {
        let mut out = self.u.clone();
        for i in 0..out.rows() {
            for (v, &s) in out.row_mut(i).iter_mut().zip(&self.s) {
                *v *= s;
            }
        }
        out
    }

    /// The paper's storage split: `A = U_r Σ^{1/2}` (m × r) and
    /// `B = Σ^{1/2} V_rᵀ` (r × n), so `A·B = U Σ Vᵀ`. Both factors are
    /// scaled in one row-major pass each (U rows elementwise by `√s`, Vᵀ
    /// rows by their own `√s[j]`) — same multiplications, unit stride.
    pub fn split_factors(&self) -> (Tensor, Tensor) {
        let sq: Vec<f32> = self.s.iter().map(|&s| s.max(0.0).sqrt()).collect();
        let mut a = self.u.clone();
        for i in 0..a.rows() {
            for (v, &q) in a.row_mut(i).iter_mut().zip(&sq) {
                *v *= q;
            }
        }
        let mut b = self.vt.clone();
        for (j, &q) in sq.iter().enumerate() {
            for v in b.row_mut(j).iter_mut() {
                *v *= q;
            }
        }
        (a, b)
    }

    /// Fraction of squared Frobenius energy captured by the retained
    /// triplets relative to `total_fro2` (‖A‖_F²).
    pub fn energy_fraction(&self, total_fro2: f64) -> f64 {
        if total_fro2 <= 0.0 {
            return 1.0;
        }
        let kept: f64 = self.s.iter().map(|&s| (s as f64) * (s as f64)).sum();
        (kept / total_fro2).min(1.0)
    }
}

/// Exact SVD via one-sided Jacobi (Hestenes). Orthogonalizes the columns of
/// `A` by plane rotations; converges quadratically. O(m·n²·sweeps) — used
/// for matrices up to ~512 per side and as the test oracle.
pub fn svd_jacobi(a: &Tensor) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    // Work column-major in f64: col[j] is a vector of length m.
    let mut cols: Vec<Vec<f64>> =
        (0..n).map(|j| (0..m).map(|i| a.at(i, j) as f64).collect()).collect();
    // V accumulates the right rotations, starts as identity (n × n).
    let mut v = vec![0.0f64; n * n];
    for j in 0..n {
        v[j * n + j] = 1.0;
    }

    let eps = 1e-12;
    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    app += cols[p][i] * cols[p][i];
                    aqq += cols[q][i] * cols[q][i];
                    apq += cols[p][i] * cols[q][i];
                }
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) off-diagonal of AᵀA.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let vp = cols[p][i];
                    let vq = cols[q][i];
                    cols[p][i] = c * vp - s * vq;
                    cols[q][i] = s * vp + c * vq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off < eps {
            break;
        }
    }

    // Singular values are the column norms; U columns the normalized columns.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = cols.iter().map(|c| c.iter().map(|x| x * x).sum::<f64>().sqrt()).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut u = Tensor::zeros(&[m, n]);
    let mut s = Vec::with_capacity(n);
    let mut vt = Tensor::zeros(&[n, n]);
    for (out_j, &j) in order.iter().enumerate() {
        let nrm = norms[j];
        s.push(nrm as f32);
        if nrm > 1e-300 {
            for i in 0..m {
                *u.at_mut(i, out_j) = (cols[j][i] / nrm) as f32;
            }
        }
        for i in 0..n {
            *vt.at_mut(out_j, i) = v[i * n + j] as f32;
        }
    }

    Svd { u, s, vt }
}

/// Keep only the top-`r` triplets of an SVD.
pub fn truncate(svd: &Svd, r: usize) -> Svd {
    let r = r.min(svd.rank());
    let (m, n) = (svd.u.rows(), svd.vt.cols());
    let mut u = Tensor::zeros(&[m, r]);
    let mut vt = Tensor::zeros(&[r, n]);
    for j in 0..r {
        for i in 0..m {
            *u.at_mut(i, j) = svd.u.at(i, j);
        }
        vt.row_mut(j).copy_from_slice(svd.vt.row(j));
    }
    Svd { u, s: svd.s[..r].to_vec(), vt }
}

/// Randomized truncated SVD (Halko et al. 2011): range sketch `Y = A·Ω`,
/// `q` power iterations with QR re-orthogonalization, small exact SVD of
/// `Qᵀ·A`. `oversample` extra sketch columns sharpen the tail.
pub fn svd_randomized(a: &Tensor, rank: usize, oversample: usize, power_iters: usize, rng: &mut Rng) -> Svd {
    svd_randomized_with(a, rank, oversample, power_iters, rng, exec::global())
}

/// [`svd_randomized`] with an explicit thread config. The subspace-iteration
/// GEMMs (`A·Ω`, `Aᵀ·Q`, `A·Z`, `Qᵀ·A`, `Q·V_b`) are the cost center and run
/// row-parallel on the deterministic executor through the shared packed
/// GEMM engine (persistent pool by default — relevant here because each
/// power iteration issues several short GEMMs, exactly the dispatch-bound
/// shape spawn-per-call was slow at). The transposed products `Aᵀ·Q` and
/// `Qᵀ·A` pack their A panels straight from the strided source, so the
/// full `m × n` transpose copy formerly paid per power iteration is gone.
/// The Householder QR and the small exact Jacobi stay serial. Output is
/// bit-identical at any `exec.threads`.
pub fn svd_randomized_with(
    a: &Tensor,
    rank: usize,
    oversample: usize,
    power_iters: usize,
    rng: &mut Rng,
    exec: ExecConfig,
) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    let r = rank.min(m.min(n)).max(1);
    let sketch = (r + oversample).min(m.min(n));

    // Y = A · Ω, Ω: n × sketch gaussian.
    let omega = Tensor::randn(&[n, sketch], rng);
    let mut q = qr_householder(&a.matmul_with(&omega, exec));

    // Power iterations: (A Aᵀ)^q Y with re-orthogonalization each half-step.
    for _ in 0..power_iters {
        let z = qr_householder(&a.t_matmul_with(&q, exec)); // n × sketch
        q = qr_householder(&a.matmul_with(&z, exec)); // m × sketch
    }

    // B = Qᵀ A  (sketch × n) — small; exact Jacobi on Bᵀ (n × sketch) keeps
    // m >= n orientation for the one-sided method.
    let b = q.t_matmul_with(a, exec);
    let svd_bt = svd_jacobi(&b.transpose_with(exec)); // Bᵀ = U_b S V_bᵀ  ⇒  B = V_b S U_bᵀ
    let r_keep = r.min(svd_bt.rank());

    // B = (V_b) S (U_bᵀ): left factors of B are V_b's columns.
    // U = Q · V_b[:, :r], Vt = U_b[:, :r]ᵀ.
    let vb = svd_bt.vt.transpose_with(exec); // sketch × sketch
    let mut vb_r = Tensor::zeros(&[vb.rows(), r_keep]);
    for j in 0..r_keep {
        for i in 0..vb.rows() {
            *vb_r.at_mut(i, j) = vb.at(i, j);
        }
    }
    let u = q.matmul_with(&vb_r, exec);
    let mut vt = Tensor::zeros(&[r_keep, n]);
    for j in 0..r_keep {
        for i in 0..n {
            *vt.at_mut(j, i) = svd_bt.u.at(i, j);
        }
    }

    Svd { u, s: svd_bt.s[..r_keep].to_vec(), vt }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn low_rank_matrix(m: usize, n: usize, r: usize, rng: &mut Rng) -> Tensor {
        let a = Tensor::randn(&[m, r], rng);
        let b = Tensor::randn(&[r, n], rng);
        a.matmul(&b)
    }

    #[test]
    fn jacobi_reconstructs_exactly() {
        let mut rng = Rng::new(71);
        let a = Tensor::randn(&[12, 8], &mut rng);
        let svd = svd_jacobi(&a);
        prop::assert_close(svd.reconstruct().data(), a.data(), 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn jacobi_singular_values_sorted_nonneg() {
        let mut rng = Rng::new(72);
        let a = Tensor::randn(&[10, 10], &mut rng);
        let svd = svd_jacobi(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(svd.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn jacobi_u_v_orthonormal() {
        let mut rng = Rng::new(73);
        let a = Tensor::randn(&[15, 9], &mut rng);
        let svd = svd_jacobi(&a);
        let utu = svd.u.t_matmul(&svd.u);
        let vvt = svd.vt.matmul(&svd.vt.transpose());
        for i in 0..9 {
            for j in 0..9 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(i, j) - want).abs() < 1e-3, "UtU[{i},{j}]={}", utu.at(i, j));
                assert!((vvt.at(i, j) - want).abs() < 1e-3, "VVt[{i},{j}]={}", vvt.at(i, j));
            }
        }
    }

    #[test]
    fn truncation_is_best_low_rank_on_known_spectrum() {
        // Diagonal matrix: truncated SVD error is exactly the dropped sigmas.
        let mut a = Tensor::zeros(&[6, 6]);
        for i in 0..6 {
            *a.at_mut(i, i) = (6 - i) as f32;
        }
        let svd = truncate(&svd_jacobi(&a), 3);
        let err = a.sub(&svd.reconstruct());
        // ‖err‖_F² = 3² + 2² + 1² = 14.
        assert!((err.fro_norm().powi(2) - 14.0).abs() < 1e-3, "{}", err.fro_norm().powi(2));
    }

    #[test]
    fn randomized_matches_jacobi_on_low_rank() {
        let mut rng = Rng::new(74);
        let a = low_rank_matrix(40, 30, 5, &mut rng);
        let rsvd = svd_randomized(&a, 5, 8, 2, &mut rng);
        // Rank-5 matrix: rank-5 randomized SVD reconstructs it (almost) exactly.
        let rel = a.sub(&rsvd.reconstruct()).fro_norm() / a.fro_norm();
        assert!(rel < 1e-3, "relative error {rel}");
    }

    #[test]
    fn randomized_close_to_optimal_on_full_rank() {
        let mut rng = Rng::new(75);
        let a = Tensor::randn(&[50, 40], &mut rng);
        let r = 10;
        let exact_err = {
            let svd = truncate(&svd_jacobi(&a), r);
            a.sub(&svd.reconstruct()).fro_norm()
        };
        let rand_err = {
            let svd = svd_randomized(&a, r, 10, 3, &mut rng);
            a.sub(&svd.reconstruct()).fro_norm()
        };
        assert!(
            rand_err <= exact_err * 1.15,
            "randomized {rand_err} vs optimal {exact_err}"
        );
    }

    #[test]
    fn split_factors_multiply_back() {
        let mut rng = Rng::new(76);
        let a = Tensor::randn(&[12, 10], &mut rng);
        let svd = truncate(&svd_jacobi(&a), 4);
        let (fa, fb) = svd.split_factors();
        assert_eq!(fa.shape(), &[12, 4]);
        assert_eq!(fb.shape(), &[4, 10]);
        prop::assert_close(fa.matmul(&fb).data(), svd.reconstruct().data(), 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn energy_fraction_monotone_in_rank() {
        let mut rng = Rng::new(77);
        let a = Tensor::randn(&[20, 16], &mut rng);
        let full = svd_jacobi(&a);
        let total = a.fro_norm().powi(2);
        let mut last = 0.0;
        for r in 1..=16 {
            let e = truncate(&full, r).energy_fraction(total);
            assert!(e >= last - 1e-9, "energy not monotone at r={r}");
            last = e;
        }
        assert!((last - 1.0).abs() < 1e-4);
    }

    #[test]
    fn zero_matrix_svd() {
        let a = Tensor::zeros(&[5, 4]);
        let svd = svd_jacobi(&a);
        assert!(svd.s.iter().all(|&s| s == 0.0));
        prop::assert_close(svd.reconstruct().data(), a.data(), 1e-9, 0.0).unwrap();
    }
}
