//! Thin QR via Householder reflections.

use crate::tensor::Tensor;

/// Thin QR factorization of `a` (m × n, m ≥ n): returns `Q` (m × n) with
/// orthonormal columns such that `Q·R = a` for upper-triangular `R`
/// (R itself is not returned — the randomized SVD only needs the range).
pub fn qr_householder(a: &Tensor) -> Tensor {
    let (m, n) = (a.rows(), a.cols());
    assert!(m >= n, "thin QR wants m >= n, got {m} x {n}");

    // Work on a mutable copy in f64 for stability.
    let mut r: Vec<f64> = a.data().iter().map(|&v| v as f64).collect();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // Householder vectors

    for k in 0..n {
        // Column k below the diagonal.
        let mut norm2 = 0.0;
        for i in k..m {
            let v = r[i * n + k];
            norm2 += v * v;
        }
        let norm = norm2.sqrt();
        let mut v = vec![0.0; m - k];
        if norm > 0.0 {
            let alpha = if r[k * n + k] >= 0.0 { -norm } else { norm };
            v[0] = r[k * n + k] - alpha;
            for i in (k + 1)..m {
                v[i - k] = r[i * n + k];
            }
            let vnorm2: f64 = v.iter().map(|x| x * x).sum();
            if vnorm2 > 1e-300 {
                // Apply H = I - 2 v vᵀ / (vᵀv) to R[k.., k..].
                for j in k..n {
                    let mut dot = 0.0;
                    for i in k..m {
                        dot += v[i - k] * r[i * n + j];
                    }
                    let f = 2.0 * dot / vnorm2;
                    for i in k..m {
                        r[i * n + j] -= f * v[i - k];
                    }
                }
            }
        }
        vs.push(v);
    }

    // Accumulate Q = H_0 · H_1 ··· H_{n-1} · [I_n; 0].
    let mut q = vec![0.0f64; m * n];
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 <= 1e-300 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[i * n + j];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                q[i * n + j] -= f * v[i - k];
            }
        }
    }

    Tensor::from_vec(&[m, n], q.into_iter().map(|v| v as f32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn q_has_orthonormal_columns() {
        let mut rng = Rng::new(61);
        let a = Tensor::randn(&[30, 8], &mut rng);
        let q = qr_householder(&a);
        let qtq = q.t_matmul(&q);
        for i in 0..8 {
            for j in 0..8 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (qtq.at(i, j) - want).abs() < 1e-4,
                    "QtQ[{i},{j}] = {}",
                    qtq.at(i, j)
                );
            }
        }
    }

    #[test]
    fn q_spans_the_column_space() {
        // Projecting A onto range(Q) must reproduce A: Q Qᵀ A == A.
        let mut rng = Rng::new(62);
        let a = Tensor::randn(&[25, 6], &mut rng);
        let q = qr_householder(&a);
        let proj = q.matmul(&q.t_matmul(&a));
        prop::assert_close(proj.data(), a.data(), 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn handles_rank_deficient_input() {
        // Two identical columns.
        let mut rng = Rng::new(63);
        let mut a = Tensor::randn(&[10, 3], &mut rng);
        let c0 = a.col(0);
        a.set_col(2, &c0);
        let q = qr_householder(&a);
        assert_eq!(q.shape(), &[10, 3]);
        assert!(q.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn square_identity() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let q = qr_householder(&a);
        let qtq = q.t_matmul(&q);
        prop::assert_close(qtq.data(), &[1.0, 0.0, 0.0, 1.0], 1e-5, 0.0).unwrap();
    }
}
