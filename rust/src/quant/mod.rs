//! Quantization: the RTN baseline the paper compares against, the
//! average-bits accounting used by Table II and by the Table-I budget
//! matching (SWSC and RTN are compared *at equal storage*), and the
//! grouped int8 storage layer ([`QuantizedTensor`]) behind the quantized
//! `.swsc` section and its fused dequantize-in-register serving path.

pub mod bits;
pub mod rtn;

pub use bits::{
    rtn_avg_bits, swsc_avg_bits, swsc_avg_bits_paper, swsc_quantized_avg_bits, BitsBreakdown,
};
pub use rtn::{dequant_u8, rtn_quantize, QuantConfig, QuantizedTensor, RtnConfig, RtnMode};
