//! Quantization: the RTN baseline the paper compares against, plus the
//! average-bits accounting used by Table II and by the Table-I budget
//! matching (SWSC and RTN are compared *at equal storage*).

pub mod bits;
pub mod rtn;

pub use bits::{rtn_avg_bits, swsc_avg_bits, swsc_avg_bits_paper, BitsBreakdown};
pub use rtn::{rtn_quantize, RtnConfig, RtnMode};
