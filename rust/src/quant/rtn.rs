//! Round-to-nearest (RTN) quantization — the paper's baseline, plus the
//! grouped int8 storage path the quantized `.swsc` section uses.
//!
//! Per-channel (per-column) affine quantization to `bits` levels: each
//! channel stores its own scale/zero-point (fp16-equivalent in the bit
//! accounting) and every weight is rounded to the nearest level. This is
//! the standard weight-only PTQ baseline; at 2 bits it collapses exactly as
//! the paper's Table I shows.
//!
//! [`QuantizedTensor`] is the *storage* variant: u8 codes with one f32
//! scale/zero per (`group` rows × one column) block, the representation
//! the fused dequantize-in-register GEMM (`tensor::gemm::PackedBQ`)
//! serves directly. Groups run down each column — the GEMM inner
//! dimension when the factor is a right operand — so a microkernel
//! panel crosses group boundaries only along k, never along the SIMD
//! lanes.

use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Symmetric (zero-point fixed at mid-range of signed levels) vs asymmetric
/// (min/max affine) RTN.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RtnMode {
    Symmetric,
    Asymmetric,
}

/// RTN configuration.
#[derive(Debug, Clone, Copy)]
pub struct RtnConfig {
    /// Bit width (levels = 2^bits).
    pub bits: u32,
    pub mode: RtnMode,
}

impl Default for RtnConfig {
    fn default() -> Self {
        RtnConfig { bits: 3, mode: RtnMode::Asymmetric }
    }
}

/// Fake-quantize `w` per channel (column): quantize then dequantize, so the
/// result is directly usable as a weight matrix. Returns the dequantized
/// matrix — storage accounting lives in [`super::bits`].
pub fn rtn_quantize(w: &Tensor, cfg: &RtnConfig) -> Tensor {
    let (m, n) = (w.rows(), w.cols());
    let levels = (1u32 << cfg.bits) as f32;
    let mut out = Tensor::zeros(&[m, n]);

    for j in 0..n {
        let col = w.col(j);
        let (deq_col, _scale, _zero) = match cfg.mode {
            RtnMode::Asymmetric => quantize_channel_asym(&col, levels),
            RtnMode::Symmetric => quantize_channel_sym(&col, levels),
        };
        out.set_col(j, &deq_col);
    }
    out
}

/// Grouped int8 quantization settings for the quantized `.swsc` section.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantConfig {
    /// Rows per quantization group (per column); each group stores one
    /// f32 scale and one f32 zero-point. Smaller groups track outliers
    /// tighter at higher metadata cost: stored bits per element are
    /// `8 + 64/group` (9.0 at the default 64).
    pub group: usize,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig { group: 64 }
    }
}

/// The one dequantization expression. `dequantize`, the fused GEMM
/// panels (`tensor::gemm::PackedBQ`), and the round-trip tests all call
/// this exact function, so every quantized path produces bitwise
/// identical f32 values from the same codes.
#[inline(always)]
pub fn dequant_u8(code: u8, scale: f32, zero: f32) -> f32 {
    (code as f32 - zero) * scale
}

/// Row-major matrix stored as u8 codes with per-(group, column) f32
/// affine parameters: `value ≈ (code − zero) · scale`. Group `g` of
/// column `j` covers rows `g·group .. min((g+1)·group, rows)` — the last
/// group of each column may be ragged.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedTensor {
    rows: usize,
    cols: usize,
    group: usize,
    /// u8 codes, row-major `rows × cols`.
    data: Vec<u8>,
    /// Per-group scales, row-major `ngroups × cols`.
    scales: Vec<f32>,
    /// Per-group zero-points, row-major `ngroups × cols`.
    zeros: Vec<f32>,
}

impl QuantizedTensor {
    /// Quantize `t` with asymmetric 256-level affine grids, one grid per
    /// (group, column) block. Constant blocks encode *exactly* (code 0,
    /// `scale = 1`, `zero = −v`); non-finite inputs are not preserved.
    pub fn quantize(t: &Tensor, cfg: &QuantConfig) -> QuantizedTensor {
        assert!(cfg.group > 0, "quantization group must be positive");
        let (rows, cols) = (t.rows(), t.cols());
        let ngroups = rows.div_ceil(cfg.group.max(1));
        let mut data = vec![0u8; rows * cols];
        let mut scales = vec![0.0f32; ngroups * cols];
        let mut zeros = vec![0.0f32; ngroups * cols];
        let d = t.data();
        for g in 0..ngroups {
            let r0 = g * cfg.group;
            let r1 = (r0 + cfg.group).min(rows);
            for j in 0..cols {
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for i in r0..r1 {
                    let v = d[i * cols + j];
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                let (scale, zero);
                if !lo.is_finite() || !hi.is_finite() || hi <= lo {
                    // Constant (or degenerate) block: `(0 − zero)·scale = v`
                    // reproduces the value exactly — codes stay 0.
                    let v = if lo.is_finite() { lo } else { 0.0 };
                    scale = 1.0;
                    zero = -v;
                } else {
                    scale = (hi - lo) / 255.0;
                    zero = (-lo / scale).round();
                    for i in r0..r1 {
                        let v = d[i * cols + j];
                        data[i * cols + j] = (v / scale + zero).round().clamp(0.0, 255.0) as u8;
                    }
                }
                scales[g * cols + j] = scale;
                zeros[g * cols + j] = zero;
            }
        }
        QuantizedTensor { rows, cols, group: cfg.group, data, scales, zeros }
    }

    /// Rebuild from raw parts (the `.swsc` reader); validates the
    /// geometry with `Err`, never panics.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        group: usize,
        data: Vec<u8>,
        scales: Vec<f32>,
        zeros: Vec<f32>,
    ) -> Result<QuantizedTensor> {
        ensure!(group > 0, "quantization group must be positive, got 0");
        let ngroups = rows.div_ceil(group);
        ensure!(
            data.len() == rows * cols,
            "quantized data holds {} codes for a {rows}x{cols} matrix",
            data.len()
        );
        ensure!(
            scales.len() == ngroups * cols && zeros.len() == ngroups * cols,
            "quantized metadata holds {} scales / {} zeros, want {} ({} groups x {cols} cols)",
            scales.len(),
            zeros.len(),
            ngroups * cols,
            ngroups
        );
        Ok(QuantizedTensor { rows, cols, group, data, scales, zeros })
    }

    /// Dequantize into a dense f32 tensor via [`dequant_u8`].
    pub fn dequantize(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.rows, self.cols]);
        let o = out.data_mut();
        for i in 0..self.rows {
            let g = i / self.group;
            for j in 0..self.cols {
                let scale = self.scales[g * self.cols + j];
                let zero = self.zeros[g * self.cols + j];
                o[i * self.cols + j] = dequant_u8(self.data[i * self.cols + j], scale, zero);
            }
        }
        out
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn group(&self) -> usize {
        self.group
    }

    /// Groups per column: `ceil(rows / group)`.
    pub fn ngroups(&self) -> usize {
        self.rows.div_ceil(self.group)
    }

    pub fn data(&self) -> &[u8] {
        &self.data
    }

    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    pub fn zeros(&self) -> &[f32] {
        &self.zeros
    }

    /// Per-element worst-case absolute reconstruction error for the block
    /// holding `(row, col)`: one affine step, plus clamp slack at the grid
    /// edges — `scale` bounds both (constant blocks are exact).
    pub fn step(&self, row: usize, col: usize) -> f32 {
        let g = row / self.group;
        self.scales[g * self.cols + col].abs()
    }

    /// Measured reconstruction error of this grid against `original`
    /// (telemetry, PR 10): `(max |err|, mean squared err)` across all
    /// elements, where err is `original − dequantize()` element-wise.
    /// Pure arithmetic on the stored codes — deterministic, and `max_abs`
    /// never exceeds the worst per-block [`QuantizedTensor::step`].
    pub fn grid_error(&self, original: &Tensor) -> (f64, f64) {
        assert_eq!(
            (self.rows, self.cols),
            (original.rows(), original.cols()),
            "grid_error shape mismatch"
        );
        let n = self.rows * self.cols;
        if n == 0 {
            return (0.0, 0.0);
        }
        let d = original.data();
        let mut max_abs = 0.0f64;
        let mut sum_sq = 0.0f64;
        for i in 0..self.rows {
            let g = i / self.group;
            for j in 0..self.cols {
                let scale = self.scales[g * self.cols + j];
                let zero = self.zeros[g * self.cols + j];
                let back = dequant_u8(self.data[i * self.cols + j], scale, zero);
                let err = (d[i * self.cols + j] - back) as f64;
                max_abs = max_abs.max(err.abs());
                sum_sq += err * err;
            }
        }
        (max_abs, sum_sq / n as f64)
    }
}

fn quantize_channel_asym(col: &[f32], levels: f32) -> (Vec<f32>, f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in col {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || !hi.is_finite() || hi <= lo {
        return (col.to_vec(), 1.0, 0.0);
    }
    let scale = (hi - lo) / (levels - 1.0);
    let zero = (-lo / scale).round();
    let deq = col
        .iter()
        .map(|&v| {
            let q = (v / scale + zero).round().clamp(0.0, levels - 1.0);
            (q - zero) * scale
        })
        .collect();
    (deq, scale, zero)
}

fn quantize_channel_sym(col: &[f32], levels: f32) -> (Vec<f32>, f32, f32) {
    let amax = col.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if amax == 0.0 {
        return (col.to_vec(), 1.0, 0.0);
    }
    // Signed levels: [-levels/2, levels/2 - 1].
    let qmax = levels / 2.0 - 1.0;
    let scale = amax / qmax;
    let deq = col
        .iter()
        .map(|&v| {
            let q = (v / scale).round().clamp(-(levels / 2.0), qmax);
            q * scale
        })
        .collect();
    (deq, scale, 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn high_bits_is_nearly_lossless() {
        let mut rng = Rng::new(81);
        let w = Tensor::randn(&[32, 32], &mut rng);
        let q = rtn_quantize(&w, &RtnConfig { bits: 12, mode: RtnMode::Asymmetric });
        assert!(w.mse(&q) < 1e-6, "mse {}", w.mse(&q));
    }

    #[test]
    fn error_shrinks_with_bits() {
        let mut rng = Rng::new(82);
        let w = Tensor::randn(&[64, 64], &mut rng);
        let mut last = f64::INFINITY;
        for bits in [2u32, 3, 4, 6, 8] {
            let q = rtn_quantize(&w, &RtnConfig { bits, mode: RtnMode::Asymmetric });
            let mse = w.mse(&q);
            assert!(mse < last, "bits={bits}: {mse} !< {last}");
            last = mse;
        }
    }

    #[test]
    fn quantized_values_on_grid() {
        let mut rng = Rng::new(83);
        let w = Tensor::randn(&[16, 4], &mut rng);
        let bits = 3u32;
        let q = rtn_quantize(&w, &RtnConfig { bits, mode: RtnMode::Asymmetric });
        // Per channel, at most 2^bits distinct values.
        for j in 0..4 {
            let mut vals: Vec<f32> = q.col(j);
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup_by(|a, b| (*a - *b).abs() < 1e-6);
            assert!(vals.len() <= 1 << bits, "channel {j}: {} levels", vals.len());
        }
    }

    #[test]
    fn constant_channel_is_exact() {
        let w = Tensor::full(&[8, 2], 3.25);
        let q = rtn_quantize(&w, &RtnConfig { bits: 2, mode: RtnMode::Asymmetric });
        prop::assert_close(q.data(), w.data(), 1e-9, 0.0).unwrap();
    }

    #[test]
    fn outliers_wreck_low_bit_rtn() {
        // The paper's motivation: a single outlier stretches the grid so the
        // bulk of the channel collapses to few levels.
        let mut rng = Rng::new(84);
        let mut w = Tensor::randn(&[128, 1], &mut rng);
        let base = rtn_quantize(&w, &RtnConfig { bits: 2, mode: RtnMode::Asymmetric });
        let base_mse = w.mse(&base);
        w.data_mut()[0] = 100.0; // inject outlier
        let hit = rtn_quantize(&w, &RtnConfig { bits: 2, mode: RtnMode::Asymmetric });
        let hit_mse = w.mse(&hit);
        // One 100σ outlier in a 128-long channel stretches the 4-level grid
        // so the bulk collapses: several-fold MSE inflation.
        assert!(hit_mse > base_mse * 3.0, "outlier should blow up RTN: {base_mse} -> {hit_mse}");
    }

    #[test]
    fn symmetric_mode_zero_maps_to_zero() {
        let w = Tensor::from_vec(&[4, 1], vec![-1.0, 0.0, 0.5, 1.0]);
        let q = rtn_quantize(&w, &RtnConfig { bits: 4, mode: RtnMode::Symmetric });
        assert_eq!(q.data()[1], 0.0);
    }

    #[test]
    fn grouped_round_trip_within_per_block_step() {
        // Ragged shapes and group sizes, incl. group > rows and group 1.
        prop::check(
            "grouped int8 round trip",
            91,
            48,
            |r| {
                let rows = 1 + r.below(40);
                let cols = 1 + r.below(9);
                let group = 1 + r.below(rows + 8);
                let mut rng = Rng::new(r.next_u64());
                (Tensor::randn(&[rows, cols], &mut rng), group)
            },
            |(w, group)| {
                let q = QuantizedTensor::quantize(w, &QuantConfig { group: *group });
                let back = q.dequantize();
                for i in 0..w.rows() {
                    for j in 0..w.cols() {
                        let err = (w.at(i, j) - back.at(i, j)).abs();
                        let bound = q.step(i, j) + 1e-5 + 1e-6 * w.at(i, j).abs();
                        if err > bound {
                            return Err(format!(
                                "({i},{j}): |{} - {}| = {err} > step {bound}",
                                w.at(i, j),
                                back.at(i, j)
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn grid_error_bounded_by_step_and_zero_on_constants() {
        let mut rng = Rng::new(86);
        let w = Tensor::randn(&[20, 5], &mut rng);
        let q = QuantizedTensor::quantize(&w, &QuantConfig { group: 8 });
        let (max_abs, mse) = q.grid_error(&w);
        let worst_step = (0..w.rows())
            .flat_map(|i| (0..w.cols()).map(move |j| (i, j)))
            .map(|(i, j)| q.step(i, j) as f64)
            .fold(0.0f64, f64::max);
        assert!(max_abs <= worst_step + 1e-6, "max {max_abs} > worst step {worst_step}");
        assert!(mse <= max_abs * max_abs + 1e-12);
        assert!(mse > 0.0, "random data cannot quantize exactly");
        // Constant blocks encode exactly ⇒ zero error.
        let c = Tensor::full(&[9, 2], 4.75);
        let qc = QuantizedTensor::quantize(&c, &QuantConfig { group: 4 });
        assert_eq!(qc.grid_error(&c), (0.0, 0.0));
        // Empty matrices don't divide by zero.
        let e = Tensor::zeros(&[0, 6]);
        let qe = QuantizedTensor::quantize(&e, &QuantConfig::default());
        assert_eq!(qe.grid_error(&e), (0.0, 0.0));
    }

    #[test]
    fn grouped_constant_blocks_are_exact() {
        let w = Tensor::full(&[13, 3], -7.5);
        let q = QuantizedTensor::quantize(&w, &QuantConfig { group: 4 });
        assert_eq!(q.dequantize(), w);
        assert_eq!(q.ngroups(), 4); // 13 rows / group 4, ragged tail of 1
        assert_eq!(q.step(0, 0), 1.0); // constant fallback grid
    }

    #[test]
    fn grouped_parts_round_trip_and_validation() {
        let mut rng = Rng::new(85);
        let w = Tensor::randn(&[10, 3], &mut rng);
        let q = QuantizedTensor::quantize(&w, &QuantConfig { group: 4 });
        let rebuilt = QuantizedTensor::from_parts(
            q.rows(),
            q.cols(),
            q.group(),
            q.data().to_vec(),
            q.scales().to_vec(),
            q.zeros().to_vec(),
        )
        .unwrap();
        assert_eq!(rebuilt, q);
        assert!(QuantizedTensor::from_parts(10, 3, 0, vec![], vec![], vec![]).is_err());
        assert!(QuantizedTensor::from_parts(10, 3, 4, vec![0; 29], vec![0.0; 9], vec![0.0; 9])
            .is_err());
        assert!(QuantizedTensor::from_parts(10, 3, 4, vec![0; 30], vec![0.0; 8], vec![0.0; 9])
            .is_err());
    }

    #[test]
    fn grouped_empty_factor_dims() {
        // r = 0 factors: m x 0 and 0 x n both quantize to empty payloads.
        let a = QuantizedTensor::quantize(&Tensor::zeros(&[6, 0]), &QuantConfig::default());
        assert_eq!((a.rows(), a.cols(), a.data().len(), a.scales().len()), (6, 0, 0, 0));
        let b = QuantizedTensor::quantize(&Tensor::zeros(&[0, 6]), &QuantConfig::default());
        assert_eq!((b.rows(), b.cols(), b.ngroups(), b.scales().len()), (0, 6, 0, 0));
        assert_eq!(b.dequantize().shape(), &[0, 6]);
    }
}
