//! Round-to-nearest (RTN) quantization — the paper's baseline.
//!
//! Per-channel (per-column) affine quantization to `bits` levels: each
//! channel stores its own scale/zero-point (fp16-equivalent in the bit
//! accounting) and every weight is rounded to the nearest level. This is
//! the standard weight-only PTQ baseline; at 2 bits it collapses exactly as
//! the paper's Table I shows.

use crate::tensor::Tensor;

/// Symmetric (zero-point fixed at mid-range of signed levels) vs asymmetric
/// (min/max affine) RTN.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RtnMode {
    Symmetric,
    Asymmetric,
}

/// RTN configuration.
#[derive(Debug, Clone, Copy)]
pub struct RtnConfig {
    /// Bit width (levels = 2^bits).
    pub bits: u32,
    pub mode: RtnMode,
}

impl Default for RtnConfig {
    fn default() -> Self {
        RtnConfig { bits: 3, mode: RtnMode::Asymmetric }
    }
}

/// Fake-quantize `w` per channel (column): quantize then dequantize, so the
/// result is directly usable as a weight matrix. Returns the dequantized
/// matrix — storage accounting lives in [`super::bits`].
pub fn rtn_quantize(w: &Tensor, cfg: &RtnConfig) -> Tensor {
    let (m, n) = (w.rows(), w.cols());
    let levels = (1u32 << cfg.bits) as f32;
    let mut out = Tensor::zeros(&[m, n]);

    for j in 0..n {
        let col = w.col(j);
        let (deq_col, _scale, _zero) = match cfg.mode {
            RtnMode::Asymmetric => quantize_channel_asym(&col, levels),
            RtnMode::Symmetric => quantize_channel_sym(&col, levels),
        };
        out.set_col(j, &deq_col);
    }
    out
}

fn quantize_channel_asym(col: &[f32], levels: f32) -> (Vec<f32>, f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in col {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || !hi.is_finite() || hi <= lo {
        return (col.to_vec(), 1.0, 0.0);
    }
    let scale = (hi - lo) / (levels - 1.0);
    let zero = (-lo / scale).round();
    let deq = col
        .iter()
        .map(|&v| {
            let q = (v / scale + zero).round().clamp(0.0, levels - 1.0);
            (q - zero) * scale
        })
        .collect();
    (deq, scale, zero)
}

fn quantize_channel_sym(col: &[f32], levels: f32) -> (Vec<f32>, f32, f32) {
    let amax = col.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if amax == 0.0 {
        return (col.to_vec(), 1.0, 0.0);
    }
    // Signed levels: [-levels/2, levels/2 - 1].
    let qmax = levels / 2.0 - 1.0;
    let scale = amax / qmax;
    let deq = col
        .iter()
        .map(|&v| {
            let q = (v / scale).round().clamp(-(levels / 2.0), qmax);
            q * scale
        })
        .collect();
    (deq, scale, 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn high_bits_is_nearly_lossless() {
        let mut rng = Rng::new(81);
        let w = Tensor::randn(&[32, 32], &mut rng);
        let q = rtn_quantize(&w, &RtnConfig { bits: 12, mode: RtnMode::Asymmetric });
        assert!(w.mse(&q) < 1e-6, "mse {}", w.mse(&q));
    }

    #[test]
    fn error_shrinks_with_bits() {
        let mut rng = Rng::new(82);
        let w = Tensor::randn(&[64, 64], &mut rng);
        let mut last = f64::INFINITY;
        for bits in [2u32, 3, 4, 6, 8] {
            let q = rtn_quantize(&w, &RtnConfig { bits, mode: RtnMode::Asymmetric });
            let mse = w.mse(&q);
            assert!(mse < last, "bits={bits}: {mse} !< {last}");
            last = mse;
        }
    }

    #[test]
    fn quantized_values_on_grid() {
        let mut rng = Rng::new(83);
        let w = Tensor::randn(&[16, 4], &mut rng);
        let bits = 3u32;
        let q = rtn_quantize(&w, &RtnConfig { bits, mode: RtnMode::Asymmetric });
        // Per channel, at most 2^bits distinct values.
        for j in 0..4 {
            let mut vals: Vec<f32> = q.col(j);
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup_by(|a, b| (*a - *b).abs() < 1e-6);
            assert!(vals.len() <= 1 << bits, "channel {j}: {} levels", vals.len());
        }
    }

    #[test]
    fn constant_channel_is_exact() {
        let w = Tensor::full(&[8, 2], 3.25);
        let q = rtn_quantize(&w, &RtnConfig { bits: 2, mode: RtnMode::Asymmetric });
        prop::assert_close(q.data(), w.data(), 1e-9, 0.0).unwrap();
    }

    #[test]
    fn outliers_wreck_low_bit_rtn() {
        // The paper's motivation: a single outlier stretches the grid so the
        // bulk of the channel collapses to few levels.
        let mut rng = Rng::new(84);
        let mut w = Tensor::randn(&[128, 1], &mut rng);
        let base = rtn_quantize(&w, &RtnConfig { bits: 2, mode: RtnMode::Asymmetric });
        let base_mse = w.mse(&base);
        w.data_mut()[0] = 100.0; // inject outlier
        let hit = rtn_quantize(&w, &RtnConfig { bits: 2, mode: RtnMode::Asymmetric });
        let hit_mse = w.mse(&hit);
        // One 100σ outlier in a 128-long channel stretches the 4-level grid
        // so the bulk collapses: several-fold MSE inflation.
        assert!(hit_mse > base_mse * 3.0, "outlier should blow up RTN: {base_mse} -> {hit_mse}");
    }

    #[test]
    fn symmetric_mode_zero_maps_to_zero() {
        let w = Tensor::from_vec(&[4, 1], vec![-1.0, 0.0, 0.5, 1.0]);
        let q = rtn_quantize(&w, &RtnConfig { bits: 4, mode: RtnMode::Symmetric });
        assert_eq!(q.data()[1], 0.0);
    }
}
