//! Average-bits accounting (paper §IV-C, Table II).
//!
//! Both methods are charged fp16 (16-bit) storage for real-valued payloads,
//! matching the paper's accounting:
//!
//! - **SWSC** on an `m × n` matrix with `k` clusters and rank `r`:
//!   centroids `m·k·16` + labels `n·⌈log2 k⌉` + factors `(m + n)·r·16` bits.
//!   For square `m = n` this is `16(k + 2r)/m + ⌈log2 k⌉/m` — the paper
//!   drops the label term and reports `16(k + 2r)/m`, which is what
//!   [`swsc_avg_bits_paper`] returns (Table II exactly).
//! - **RTN** at `b` bits per weight with per-channel fp16 scale+zero:
//!   `b + 32/m` bits per weight.

/// Detailed storage breakdown for one compressed matrix, in bits.
#[derive(Debug, Clone, PartialEq)]
pub struct BitsBreakdown {
    pub centroid_bits: u64,
    pub label_bits: u64,
    pub factor_bits: u64,
    pub total_bits: u64,
    /// Bits per original weight element.
    pub avg_bits: f64,
}

/// Exact SWSC storage accounting for an `m × n` matrix.
pub fn swsc_avg_bits(m: usize, n: usize, k: usize, r: usize) -> BitsBreakdown {
    let payload = 16u64; // fp16 accounting
    let centroid_bits = (m * k) as u64 * payload;
    let label_bits = n as u64 * ceil_log2(k) as u64;
    let factor_bits = ((m + n) * r) as u64 * payload;
    let total_bits = centroid_bits + label_bits + factor_bits;
    let avg_bits = total_bits as f64 / (m as f64 * n as f64);
    BitsBreakdown { centroid_bits, label_bits, factor_bits, total_bits, avg_bits }
}

/// The paper's simplified formula for square matrices: `16(k + 2r)/m`.
/// Reproduces Table II: for m = 4096, k = 128 → 0.5, r = 64 → 0.5, etc.
pub fn swsc_avg_bits_paper(m: usize, k: usize, r: usize) -> f64 {
    16.0 * (k as f64 + 2.0 * r as f64) / m as f64
}

/// RTN storage: `b` bits/weight + per-channel fp16 scale and zero-point.
pub fn rtn_avg_bits(m: usize, _n: usize, b: u32) -> f64 {
    b as f64 + 32.0 / m as f64
}

/// Choose `(k, r)` for a target average-bits budget on an `m × n` matrix,
/// splitting the budget between clusters and rank according to
/// `rank_share ∈ [0, 1]` (the paper's Table II uses an even split:
/// 1 bit of clusters + 1 bit of rank = 2 avg bits).
pub fn swsc_params_for_bits(m: usize, target_bits: f64, rank_share: f64) -> (usize, usize) {
    let share = rank_share.clamp(0.0, 1.0);
    let k_bits = target_bits * (1.0 - share);
    let r_bits = target_bits * share;
    // centroids: 16k/m bits ⇒ k = k_bits·m/16; factors: 32r/m ⇒ r = r_bits·m/32.
    let k = ((k_bits * m as f64) / 16.0).round().max(1.0) as usize;
    let r = ((r_bits * m as f64) / 32.0).round().max(0.0) as usize;
    (k.max(1), r)
}

fn ceil_log2(k: usize) -> u32 {
    if k <= 1 {
        0
    } else {
        (usize::BITS - (k - 1).leading_zeros()).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table II of the paper, verbatim: m = 4096.
    #[test]
    fn paper_table2_clusters() {
        assert_eq!(swsc_avg_bits_paper(4096, 128, 0), 0.5);
        assert_eq!(swsc_avg_bits_paper(4096, 256, 0), 1.0);
        assert_eq!(swsc_avg_bits_paper(4096, 512, 0), 2.0);
    }

    #[test]
    fn paper_table2_rank() {
        assert_eq!(swsc_avg_bits_paper(4096, 0, 64), 0.5);
        assert_eq!(swsc_avg_bits_paper(4096, 0, 128), 1.0);
        assert_eq!(swsc_avg_bits_paper(4096, 0, 256), 2.0);
    }

    #[test]
    fn exact_vs_paper_label_overhead_is_small() {
        let exact = swsc_avg_bits(4096, 4096, 256, 128);
        let paper = swsc_avg_bits_paper(4096, 256, 128);
        let overhead = exact.avg_bits - paper;
        assert!(overhead > 0.0 && overhead < 0.01, "label overhead {overhead}");
    }

    #[test]
    fn params_for_bits_round_trip() {
        for &m in &[256usize, 512, 4096] {
            for &target in &[1.0f64, 2.0, 3.0] {
                let (k, r) = swsc_params_for_bits(m, target, 0.5);
                let got = swsc_avg_bits_paper(m, k, r);
                assert!(
                    (got - target).abs() < 0.25,
                    "m={m} target={target}: k={k} r={r} -> {got}"
                );
            }
        }
    }

    #[test]
    fn rank_share_extremes() {
        let (k, r) = swsc_params_for_bits(4096, 2.0, 0.0);
        assert_eq!((k, r), (512, 0));
        let (k, r) = swsc_params_for_bits(4096, 2.0, 1.0);
        assert_eq!(k, 1); // clamped to at least one cluster
        assert_eq!(r, 256);
    }

    #[test]
    fn rtn_bits_accounting() {
        assert!((rtn_avg_bits(4096, 4096, 3) - 3.0078125).abs() < 1e-9);
        assert!((rtn_avg_bits(256, 256, 2) - 2.125).abs() < 1e-9);
    }

    #[test]
    fn ceil_log2_edges() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(256), 8);
        assert_eq!(ceil_log2(257), 9);
    }

    #[test]
    fn monotone_in_k_and_r() {
        let mut last = 0.0;
        for k in [8, 16, 32, 64] {
            let b = swsc_avg_bits(256, 256, k, 4).avg_bits;
            assert!(b > last);
            last = b;
        }
        let mut last = 0.0;
        for r in [1, 2, 4, 8] {
            let b = swsc_avg_bits(256, 256, 8, r).avg_bits;
            assert!(b > last);
            last = b;
        }
    }
}
