//! Average-bits accounting (paper §IV-C, Table II).
//!
//! Both methods are charged fp16 (16-bit) storage for real-valued payloads,
//! matching the paper's accounting:
//!
//! - **SWSC** on an `m × n` matrix with `k` clusters and rank `r`:
//!   centroids `m·k·16` + labels `n·⌈log2 k⌉` + factors `(m + n)·r·16` bits.
//!   For square `m = n` this is `16(k + 2r)/m + ⌈log2 k⌉/m` — the paper
//!   drops the label term and reports `16(k + 2r)/m`, which is what
//!   [`swsc_avg_bits_paper`] returns (Table II exactly).
//! - **RTN** at `b` bits per weight with per-channel fp16 scale+zero:
//!   `b + 32/m` bits per weight.

/// Detailed storage breakdown for one compressed matrix, in bits.
#[derive(Debug, Clone, PartialEq)]
pub struct BitsBreakdown {
    pub centroid_bits: u64,
    pub label_bits: u64,
    pub factor_bits: u64,
    pub total_bits: u64,
    /// Bits per original weight element.
    pub avg_bits: f64,
}

/// Exact SWSC storage accounting for an `m × n` matrix.
pub fn swsc_avg_bits(m: usize, n: usize, k: usize, r: usize) -> BitsBreakdown {
    let payload = 16u64; // fp16 accounting
    let centroid_bits = (m * k) as u64 * payload;
    let label_bits = n as u64 * ceil_log2(k) as u64;
    let factor_bits = ((m + n) * r) as u64 * payload;
    let total_bits = centroid_bits + label_bits + factor_bits;
    let avg_bits = total_bits as f64 / (m as f64 * n as f64);
    BitsBreakdown { centroid_bits, label_bits, factor_bits, total_bits, avg_bits }
}

/// The paper's simplified formula for square matrices: `16(k + 2r)/m`.
/// Reproduces Table II: for m = 4096, k = 128 → 0.5, r = 64 → 0.5, etc.
pub fn swsc_avg_bits_paper(m: usize, k: usize, r: usize) -> f64 {
    16.0 * (k as f64 + 2.0 * r as f64) / m as f64
}

/// RTN storage: `b` bits/weight + per-channel fp16 scale and zero-point.
pub fn rtn_avg_bits(m: usize, _n: usize, b: u32) -> f64 {
    b as f64 + 32.0 / m as f64
}

/// Actual stored bits for a *quantized* `.swsc` entry (PR 6): int8 codes
/// for `R` (`m × k`), `A` (`m × r`), `B` (`r × n`) plus one f32
/// scale + zero per `group`-row column block of each factor, and labels
/// bit-packed to `⌈log2 k⌉` bits. This is what the container serializes —
/// compare against [`swsc_avg_bits`]'s fp16 estimate.
pub fn swsc_quantized_avg_bits(
    m: usize,
    n: usize,
    k: usize,
    r: usize,
    group: usize,
) -> BitsBreakdown {
    let group = group.max(1);
    // 64 bits of scale+zero metadata per (group, column) block.
    let meta = |rows: usize, cols: usize| (rows.div_ceil(group) * cols) as u64 * 64;
    let centroid_bits = (m * k) as u64 * 8 + meta(m, k);
    let label_bits = n as u64 * ceil_log2(k) as u64;
    let factor_bits = ((m + n) * r) as u64 * 8 + meta(m, r) + meta(r, n);
    let total_bits = centroid_bits + label_bits + factor_bits;
    let avg_bits = total_bits as f64 / (m as f64 * n as f64).max(1.0);
    BitsBreakdown { centroid_bits, label_bits, factor_bits, total_bits, avg_bits }
}

/// Choose `(k, r)` for a target average-bits budget on an `m × n` matrix,
/// splitting the budget between clusters and rank according to
/// `rank_share ∈ [0, 1]` (the paper's Table II uses an even split:
/// 1 bit of clusters + 1 bit of rank = 2 avg bits).
pub fn swsc_params_for_bits(m: usize, target_bits: f64, rank_share: f64) -> (usize, usize) {
    let share = rank_share.clamp(0.0, 1.0);
    let k_bits = target_bits * (1.0 - share);
    let r_bits = target_bits * share;
    // centroids: 16k/m bits ⇒ k = k_bits·m/16; factors: 32r/m ⇒ r = r_bits·m/32.
    let k = ((k_bits * m as f64) / 16.0).round().max(1.0) as usize;
    let r = ((r_bits * m as f64) / 32.0).round().max(0.0) as usize;
    (k.max(1), r)
}

fn ceil_log2(k: usize) -> u32 {
    if k <= 1 {
        0
    } else {
        (usize::BITS - (k - 1).leading_zeros()).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table II of the paper, verbatim: m = 4096.
    #[test]
    fn paper_table2_clusters() {
        assert_eq!(swsc_avg_bits_paper(4096, 128, 0), 0.5);
        assert_eq!(swsc_avg_bits_paper(4096, 256, 0), 1.0);
        assert_eq!(swsc_avg_bits_paper(4096, 512, 0), 2.0);
    }

    #[test]
    fn paper_table2_rank() {
        assert_eq!(swsc_avg_bits_paper(4096, 0, 64), 0.5);
        assert_eq!(swsc_avg_bits_paper(4096, 0, 128), 1.0);
        assert_eq!(swsc_avg_bits_paper(4096, 0, 256), 2.0);
    }

    #[test]
    fn exact_vs_paper_label_overhead_is_small() {
        let exact = swsc_avg_bits(4096, 4096, 256, 128);
        let paper = swsc_avg_bits_paper(4096, 256, 128);
        let overhead = exact.avg_bits - paper;
        assert!(overhead > 0.0 && overhead < 0.01, "label overhead {overhead}");
    }

    #[test]
    fn params_for_bits_round_trip() {
        for &m in &[256usize, 512, 4096] {
            for &target in &[1.0f64, 2.0, 3.0] {
                let (k, r) = swsc_params_for_bits(m, target, 0.5);
                let got = swsc_avg_bits_paper(m, k, r);
                assert!(
                    (got - target).abs() < 0.25,
                    "m={m} target={target}: k={k} r={r} -> {got}"
                );
            }
        }
    }

    #[test]
    fn rank_share_extremes() {
        let (k, r) = swsc_params_for_bits(4096, 2.0, 0.0);
        assert_eq!((k, r), (512, 0));
        let (k, r) = swsc_params_for_bits(4096, 2.0, 1.0);
        assert_eq!(k, 1); // clamped to at least one cluster
        assert_eq!(r, 256);
    }

    #[test]
    fn rtn_bits_accounting() {
        assert!((rtn_avg_bits(4096, 4096, 3) - 3.0078125).abs() < 1e-9);
        assert!((rtn_avg_bits(256, 256, 2) - 2.125).abs() < 1e-9);
    }

    #[test]
    fn ceil_log2_edges() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(256), 8);
        assert_eq!(ceil_log2(257), 9);
    }

    #[test]
    fn quantized_bits_vs_fp16_estimate() {
        // int8 codes + 64/group metadata ≈ 9 bits/elem at group 64, vs 16
        // for the fp16 estimate: well under the 0.35x-of-f32 acceptance
        // bound (9/32 ≈ 0.28) and a ~1.7x shrink vs fp16.
        let q = swsc_quantized_avg_bits(4096, 4096, 256, 128, 64);
        let e = swsc_avg_bits(4096, 4096, 256, 128);
        assert_eq!(q.label_bits, e.label_bits);
        let ratio = q.total_bits as f64 / e.total_bits as f64;
        assert!(ratio > 0.5 && ratio < 0.6, "int8/fp16 ratio {ratio}");
        // Payload share vs f32 (32 bits/elem-equivalent of the same counts).
        let f32_bits = 2.0 * e.total_bits as f64 - e.label_bits as f64;
        assert!(q.total_bits as f64 / f32_bits < 0.35, "vs f32: {}", q.total_bits as f64 / f32_bits);
    }

    #[test]
    fn quantized_bits_ragged_groups() {
        // 10-row factors at group 4 -> 3 groups per column.
        let q = swsc_quantized_avg_bits(10, 6, 4, 2, 4);
        assert_eq!(q.centroid_bits, (10 * 4 * 8 + 3 * 4 * 64) as u64);
        assert_eq!(q.factor_bits, ((10 + 6) * 2 * 8 + 3 * 2 * 64 + 6 * 64) as u64);
        assert_eq!(q.label_bits, 6 * 2);
    }

    #[test]
    fn monotone_in_k_and_r() {
        let mut last = 0.0;
        for k in [8, 16, 32, 64] {
            let b = swsc_avg_bits(256, 256, k, 4).avg_bits;
            assert!(b > last);
            last = b;
        }
        let mut last = 0.0;
        for r in [1, 2, 4, 8] {
            let b = swsc_avg_bits(256, 256, 8, r).avg_bits;
            assert!(b > last);
            last = b;
        }
    }
}
