//! Deterministic pseudo-random number generation.
//!
//! Everything in this repo that needs randomness (corpus synthesis, weight
//! init, k-means++ seeding, randomized SVD test sketches, property tests)
//! goes through this SplitMix64-based generator so runs are reproducible
//! from a single seed. No external crates.

/// SplitMix64 PRNG — tiny, fast, passes BigCrush for our purposes, and
/// trivially seedable. Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second normal variate from the Box–Muller pair.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds ⇒ equal streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare_normal: None }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)`. `n` must be non-zero.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping; bias is negligible for our n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller, cached in pairs.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 exactly.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with explicit mean/std, as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Falls back to uniform if all weights are zero.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent child stream (for per-worker determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(4);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
