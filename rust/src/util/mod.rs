//! Small shared utilities: deterministic RNG and a minimal
//! property-testing harness (the vendored crate set has no `proptest`).
//! Timing helpers live in [`crate::obs::prof`] — the one timing utility.

pub mod prop;
pub mod rng;

/// Human-readable byte size (`12.3 MiB`).
pub fn human_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
