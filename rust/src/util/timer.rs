//! Wallclock timing helpers shared by the bench harness and the coordinator
//! metrics.

use std::time::Instant;

/// Measure the wallclock time of `f`, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// A simple running statistics accumulator (count / mean / min / max / p50
/// approximation via stored samples when small).
#[derive(Debug, Default, Clone)]
pub struct Stats {
    samples: Vec<f64>,
}

impl Stats {
    pub fn new() -> Self {
        Stats { samples: Vec::new() }
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Percentile via nearest-rank on a sorted copy (fine for bench sizes).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = Stats::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.percentile(50.0) - 2.0).abs() <= 1.0);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
