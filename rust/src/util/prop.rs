//! Minimal property-testing harness.
//!
//! The vendored crate set does not include `proptest`, so this module
//! provides the small subset we need: run a property over many randomly
//! generated cases with a fixed seed, and on failure report the case index
//! and seed so it can be replayed exactly.

use crate::util::rng::Rng;

/// Number of cases per property (overridable via `SWSC_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("SWSC_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

/// Run `prop` over `cases` random cases. `gen` builds a case from the RNG;
/// `prop` returns `Err(msg)` to fail. Panics with seed + case on failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case}/{cases} (seed {seed}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Convenience: assert two f32 slices are close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("mismatch at {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("u64 parity", 1, 32, |r| r.next_u64(), |&x| {
            if x % 2 == 0 || x % 2 == 1 { Ok(()) } else { Err("impossible".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn check_reports_failure() {
        check("always fails", 2, 4, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-7], 1e-6, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-6, 0.0).is_err());
    }
}
