//! # SWSC — Shared Weight for Similar Channel
//!
//! A full reproduction of *"SWSC: Shared Weight for Similar Channel in LLM"*
//! (Zeng et al., 2025) as a three-layer rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the coordinator: per-matrix compression job
//!   scheduling, a batched evaluation service, a compressed-domain
//!   inference engine ([`infer`]: forward passes straight from `.swsc`
//!   factors, no reconstruction), a batched serving layer ([`serve`]:
//!   micro-batch coalescing, multi-model registry, admission-controlled
//!   backpressure), training/eval drivers, and every
//!   substrate the paper depends on (K-Means, SVD, RTN, tokenizer,
//!   corpus, checkpoint formats) built from scratch.
//! - **Layer 2 (`python/compile/model.py`)** — the transformer forward /
//!   backward and the compressed forward, lowered once to HLO text.
//! - **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for K-Means
//!   assignment/update, SWSC reconstruction, RTN fake-quant, and the fused
//!   decompress-matmul, all validated against pure-jnp oracles.
//!
//! Python runs only at build time (`make artifacts`); the rust binary loads
//! `artifacts/*.hlo.txt` through PJRT and is self-contained afterwards.
//!
//! Compression-time compute (matmul, Lloyd steps, randomized-SVD GEMMs, the
//! per-matrix driver) is parallelized through the [`exec`] module, whose
//! deterministic chunked scheduling keeps every numeric result bit-identical
//! at any thread count (`SWSC_THREADS` overrides the default of all
//! available cores; `1` reproduces the serial path exactly).
//!
//! ## Quick tour
//!
//! ```no_run
//! use swsc::compress::{SwscConfig, compress_matrix};
//! use swsc::tensor::Tensor;
//! use swsc::util::rng::Rng;
//!
//! let mut rng = Rng::new(0xC0FFEE);
//! let w = Tensor::randn(&[256, 256], &mut rng);
//! let cfg = SwscConfig { clusters: 16, rank: 8, ..Default::default() };
//! let compressed = compress_matrix(&w, &cfg);
//! let restored = compressed.reconstruct();
//! println!("avg bits: {:.3}", compressed.avg_bits());
//! println!("mse: {:.3e}", restored.mse(&w));
//! ```

pub mod bench;
pub mod compress;
pub mod coordinator;
pub mod eval;
pub mod exec;
pub mod infer;
pub mod io;
pub mod kmeans;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod text;
pub mod train;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
