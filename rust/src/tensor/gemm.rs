//! Packed register-tiled GEMM engine — the L3 CPU fast path.
//!
//! The cache-blocked i-k-j kernel that previously served every GEMM
//! ([`crate::tensor::matmul_band`], kept as the [`GemmKernel::Blocked`]
//! baseline/oracle) pays a load *and* a store of the output row for every
//! multiply-add: `orow[j] += aik * brow[j]` round-trips the accumulator
//! through L1 on each k step. This module replaces it with the standard
//! packed-panel design:
//!
//! - **B is packed into column panels** of `NR` f32 lanes (the SIMD
//!   register width, picked once at startup — see [`tile`]). Within a
//!   panel, the `NR` values of each k step are contiguous, so the
//!   microkernel's j-loop is a unit-stride vector load regardless of `n`.
//! - **A is packed into row panels** of `MR` rows, column-major within the
//!   panel (`ap[k·MR + i]`), so each k step reads one contiguous `MR`-chunk.
//!   The packing routine also accepts a *transposed-stride* source
//!   ([`ASrc::Cols`]): `t_matmul` packs `Aᵀ` panels directly out of the
//!   row-major `k × m` buffer instead of materializing an `m × k` transpose
//!   first — that copy used to be paid on every `AᵀQ` of each SVD power
//!   iteration.
//! - The **microkernel** holds an `MR × NR` accumulator block in registers
//!   across the whole k loop and spills it exactly once. The unrolled
//!   j-loop autovectorizes (dispatched through an AVX2 `target_feature`
//!   wrapper when the CPU has it, so vector codegen does not depend on
//!   `-C target-cpu`).
//!
//! ## Why the results are bit-identical to the old kernel
//!
//! Every kernel in this crate — naive triple loop, blocked i-k-j, and this
//! packed engine — computes each output element as a **single f32
//! accumulator over strictly increasing k**. Rust/LLVM never contracts
//! `mul + add` into FMA without explicit fast-math, and vectorizing the
//! j-loop only runs independent elements in lanes, so all three kernels
//! produce identical bits for every element. Tile sizes (`MR`/`NR`), panel
//! boundaries, band boundaries, and thread counts can all vary freely —
//! including across machines — without moving a single bit. That is what
//! keeps the `SWSC_THREADS` invariance contract, the blocked-vs-reference
//! Lloyd equality, and the golden `.swsc` fixture bytes intact with no
//! regeneration (see `tests/fixtures/README.md` for the policy if a future
//! kernel *does* change the accumulation order). The unit tests below pin
//! packed == naive **bitwise** over every MR/NR remainder combination.
//!
//! Kernel selection is process-wide ([`kernel`]/[`set_kernel`], env
//! `SWSC_GEMM_KERNEL=blocked`), mirroring the `ExecBackend::SpawnPerCall`
//! pattern: the old kernel survives purely as a bench baseline and
//! cross-check oracle for `packed_vs_blocked_*` rows in
//! `benches/hotpath.rs`.

//! ## The quantized panel variant (PR 6)
//!
//! [`PackedBQ`] is [`PackedB`] with the f32 lanes replaced by u8 codes
//! plus per-(group, lane) f32 scale/zero rows: the panels are packed
//! directly from a grouped int8 quantized right operand
//! ([`crate::quant::QuantizedTensor`]) and the microkernel dequantizes
//! each k-row **in registers** ([`crate::quant::dequant_u8`]) before the
//! usual mul+add — no dense f32 copy of the operand ever exists. Because
//! the dequantized value is a pure per-element function of
//! `(code, scale, zero)` and the accumulation is the identical
//! single-register increasing-k sum, the fused path is **bitwise equal**
//! to dequantize-then-f32-GEMM at any tile size, band split, or thread
//! count; only against the *original* (pre-quantization) weights is
//! there a tolerance, bounded per element by the group's grid step (see
//! `tests/fixtures/README.md`). The panels are ~4× smaller than their
//! f32 twins (`footprint_bytes`), which is the point: serving is
//! memory-bandwidth-bound.

use crate::exec::{self, ExecConfig};
use crate::quant::dequant_u8;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Which GEMM implementation carries `Tensor::matmul`/`t_matmul` and the
/// k-means cross-term tiles. Outputs are bit-identical between kernels —
/// both are single-accumulator increasing-k sums — so this is purely a
/// wall-clock/bench knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmKernel {
    /// Packed panels + register-tiled microkernel (default).
    Packed,
    /// The pre-PR-3 cache-blocked i-k-j kernel, kept as the bench baseline
    /// and as a cross-check oracle.
    Blocked,
}

// 0 = unresolved, 1 = Packed, 2 = Blocked.
static KERNEL: AtomicU8 = AtomicU8::new(0);

/// Current kernel; first call resolves `SWSC_GEMM_KERNEL` (`"blocked"`
/// selects [`GemmKernel::Blocked`], anything else the packed engine).
pub fn kernel() -> GemmKernel {
    match KERNEL.load(Ordering::Relaxed) {
        1 => GemmKernel::Packed,
        2 => GemmKernel::Blocked,
        _ => {
            let resolved = match std::env::var("SWSC_GEMM_KERNEL").ok().as_deref() {
                Some("blocked") => GemmKernel::Blocked,
                _ => GemmKernel::Packed,
            };
            set_kernel(resolved);
            resolved
        }
    }
}

/// Override the kernel process-wide. Intended for the bench harness and
/// parity tests; safe to flip at any time because both kernels produce
/// bit-identical outputs.
pub fn set_kernel(k: GemmKernel) {
    KERNEL.store(
        match k {
            GemmKernel::Packed => 1,
            GemmKernel::Blocked => 2,
        },
        Ordering::Relaxed,
    );
}

/// Microkernel tile: `mr` packed A rows × `nr` packed B columns held in
/// registers. `nr` is the SIMD lane budget per row (8 or 16 f32), `mr` the
/// row unroll (4 or 8) — together sized so the accumulator block plus one B
/// row and one A broadcast stay inside the architectural vector registers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    pub mr: usize,
    pub nr: usize,
}

/// The process-wide tile, chosen once at startup from CPU capabilities:
/// 4×16 on avx512f hosts, 8×8 otherwise (8 ymm accumulator registers at
/// AVX2 width). The 4×16 shape pays off on avx512f machines twice over:
/// at the default baseline/AVX2 codegen it halves the A broadcasts per MAC
/// versus 8×8 at identical accumulator register pressure (8 ymm either
/// way), and when the crate is additionally built with AVX-512 codegen
/// (`-C target-cpu=native`), `target_feature(enable = "avx2")` extends the
/// base feature set, so each 16-lane row becomes a single zmm register.
/// (A dedicated `avx512f` target-feature wrapper is deliberately not used:
/// it was only stabilized in much newer rustc than this crate assumes.)
/// Because every kernel is a per-element increasing-k sum, the choice
/// affects only wall-clock — results are identical across machines.
pub fn tile() -> Tile {
    static TILE: OnceLock<Tile> = OnceLock::new();
    *TILE.get_or_init(detect_tile)
}

fn detect_tile() -> Tile {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx512f") {
            return Tile { mr: 4, nr: 16 };
        }
    }
    Tile { mr: 8, nr: 8 }
}

/// Below this many elements, packing B runs inline serial (pure copy —
/// same bar as the transpose threshold in `tensor::ops`).
const PACK_PARALLEL_ELEMS: usize = 1 << 16;

/// How many B panels each parallel packing chunk covers.
const PACK_PANELS_PER_CHUNK: usize = 8;

/// `B` repacked into `⌈n/nr⌉` column panels of `k × nr` (zero-padded past
/// column `n`). Shared read-only by every row band of a GEMM, so it is
/// packed once per call, not per band.
pub(crate) struct PackedB {
    data: Vec<f32>,
    k: usize,
    n: usize,
    nr: usize,
}

impl PackedB {
    fn npanels(&self) -> usize {
        self.n.div_ceil(self.nr)
    }

    fn panel(&self, p: usize) -> &[f32] {
        &self.data[p * self.k * self.nr..(p + 1) * self.k * self.nr]
    }

    pub(crate) fn kdim(&self) -> usize {
        self.k
    }

    pub(crate) fn ncols(&self) -> usize {
        self.n
    }

    /// Bytes the packed panels occupy — the panel-cache footprint.
    pub(crate) fn footprint_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Pack row-major `k × n` B into [`PackedB`] panels. Disjoint writes into
/// pre-assigned panel slots — identical at any thread count.
pub(crate) fn pack_b(b: &[f32], k: usize, n: usize, exec: ExecConfig) -> PackedB {
    let nr = tile().nr;
    if k == 0 || n == 0 {
        return PackedB { data: Vec::new(), k, n, nr };
    }
    let np = n.div_ceil(nr);
    let mut data = vec![0.0f32; np * k * nr];
    let exec = if k * n < PACK_PARALLEL_ELEMS { ExecConfig::serial() } else { exec };
    // One "row" per panel: band over panels, each chunk packing its own
    // disjoint panel slots.
    exec::for_row_bands(exec, &mut data, np, k * nr, PACK_PANELS_PER_CHUNK, |p0, band| {
        let pcount = band.len() / (k * nr);
        for pi in 0..pcount {
            let p = p0 + pi;
            let j0 = p * nr;
            let jtake = nr.min(n - j0);
            let panel = &mut band[pi * k * nr..(pi + 1) * k * nr];
            for kk in 0..k {
                let src = &b[kk * n + j0..kk * n + j0 + jtake];
                panel[kk * nr..kk * nr + jtake].copy_from_slice(src);
                // Columns jtake..nr stay zero (ragged right edge); their
                // lanes compute values that are never copied out.
            }
        }
    });
    PackedB { data, k, n, nr }
}

/// Where the left operand's rows come from.
#[derive(Clone, Copy)]
pub(crate) enum ASrc<'a> {
    /// Row-major `m × k`: logical element `(i, kk)` at `data[i·k + kk]`.
    Rows { data: &'a [f32], k: usize },
    /// Transposed-stride source: the logical `m × k` operand is stored as a
    /// row-major `k × m` buffer (leading dimension `ld = m`), so element
    /// `(i, kk)` sits at `data[kk·ld + i]`. Packing reads contiguous
    /// `MR`-length runs per k step — no transpose materialization.
    Cols { data: &'a [f32], ld: usize },
}

/// Pack `take ≤ mr` logical A rows starting at `row0` into the
/// column-major panel `ap[kk·mr + r]`. Rows `take..mr` are zero padding;
/// their microkernel outputs are discarded, so the pad value is irrelevant.
fn pack_a_panel(a: ASrc<'_>, row0: usize, take: usize, mr: usize, kdim: usize, ap: &mut [f32]) {
    if take < mr {
        ap.fill(0.0);
    }
    match a {
        ASrc::Rows { data, k } => {
            debug_assert_eq!(k, kdim);
            for r in 0..take {
                let row = &data[(row0 + r) * kdim..(row0 + r + 1) * kdim];
                for (kk, &v) in row.iter().enumerate() {
                    ap[kk * mr + r] = v;
                }
            }
        }
        ASrc::Cols { data, ld } => {
            for kk in 0..kdim {
                let src = &data[kk * ld + row0..kk * ld + row0 + take];
                ap[kk * mr..kk * mr + take].copy_from_slice(src);
            }
        }
    }
}

/// The register-tiled microkernel: `out[i·NR + j] = Σ_k ap[k·MR+i]·bp[k·NR+j]`.
///
/// The accumulator block is a local `[[f32; NR]; MR]` that LLVM keeps in
/// vector registers across the k loop (no aliasing: inputs are shared
/// borrows, `acc` is local) and spills exactly once at the end. Each
/// element is one scalar accumulator over increasing `kk` — the
/// bit-determinism contract.
#[inline(always)]
fn micro_body<const MR: usize, const NR: usize>(
    kdim: usize,
    ap: &[f32],
    bp: &[f32],
    out: &mut [f32],
) {
    debug_assert!(ap.len() >= kdim * MR);
    debug_assert!(bp.len() >= kdim * NR);
    debug_assert!(out.len() >= MR * NR);
    let mut acc = [[0.0f32; NR]; MR];
    for kk in 0..kdim {
        let arow: &[f32; MR] = (&ap[kk * MR..kk * MR + MR]).try_into().unwrap();
        let brow: &[f32; NR] = (&bp[kk * NR..kk * NR + NR]).try_into().unwrap();
        for i in 0..MR {
            let aik = arow[i];
            for j in 0..NR {
                acc[i][j] += aik * brow[j];
            }
        }
    }
    for i in 0..MR {
        for j in 0..NR {
            out[i * NR + j] = acc[i][j];
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod simd {
    use super::micro_body;
    use std::sync::OnceLock;

    fn avx2() -> bool {
        static AVX2: OnceLock<bool> = OnceLock::new();
        *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
    }

    // `target_feature` wrappers: the generic body inlines into a function
    // compiled with AVX2 codegen, so the j-loop vectorizes at ymm width
    // even when the crate is built for baseline x86-64. No fast-math flags
    // are involved, so the arithmetic (mul then add, per element, in k
    // order) is bit-identical to the fallback body.
    #[target_feature(enable = "avx2")]
    unsafe fn body_8x8(kdim: usize, ap: &[f32], bp: &[f32], out: &mut [f32]) {
        micro_body::<8, 8>(kdim, ap, bp, out)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn body_4x16(kdim: usize, ap: &[f32], bp: &[f32], out: &mut [f32]) {
        micro_body::<4, 16>(kdim, ap, bp, out)
    }

    pub(super) fn micro_8x8(kdim: usize, ap: &[f32], bp: &[f32], out: &mut [f32]) -> bool {
        if !avx2() {
            return false;
        }
        // SAFETY: AVX2 support verified at runtime above.
        unsafe { body_8x8(kdim, ap, bp, out) };
        true
    }

    pub(super) fn micro_4x16(kdim: usize, ap: &[f32], bp: &[f32], out: &mut [f32]) -> bool {
        if !avx2() {
            return false;
        }
        // SAFETY: AVX2 support verified at runtime above.
        unsafe { body_4x16(kdim, ap, bp, out) };
        true
    }
}

#[cfg(not(target_arch = "x86_64"))]
mod simd {
    pub(super) fn micro_8x8(_: usize, _: &[f32], _: &[f32], _: &mut [f32]) -> bool {
        false
    }

    pub(super) fn micro_4x16(_: usize, _: &[f32], _: &[f32], _: &mut [f32]) -> bool {
        false
    }
}

fn run_micro(t: Tile, kdim: usize, ap: &[f32], bp: &[f32], out: &mut [f32]) {
    match (t.mr, t.nr) {
        (8, 8) => {
            if !simd::micro_8x8(kdim, ap, bp, out) {
                micro_body::<8, 8>(kdim, ap, bp, out);
            }
        }
        (4, 16) => {
            if !simd::micro_4x16(kdim, ap, bp, out) {
                micro_body::<4, 16>(kdim, ap, bp, out);
            }
        }
        _ => unreachable!("unsupported GEMM tile {t:?}"),
    }
}

/// One packed A panel driven across every B panel: writes (or accumulates
/// onto) output rows `i0..i0 + take` of the `? × n` band `out`. Shared by
/// the pack-on-the-fly path ([`gemm_rows`]) and the pre-packed path
/// ([`gemm_rows_prepacked`]) so both run the identical microkernel calls
/// and output copies — bitwise interchangeable by construction.
#[allow(clippy::too_many_arguments)]
fn emit_panel_rows(
    t: Tile,
    kdim: usize,
    apanel: &[f32],
    pb: &PackedB,
    i0: usize,
    take: usize,
    out: &mut [f32],
    add: bool,
    scratch: &mut [f32],
) {
    let nr = t.nr;
    let n = pb.n;
    for p in 0..pb.npanels() {
        run_micro(t, kdim, apanel, pb.panel(p), scratch);
        let j0 = p * nr;
        let jtake = nr.min(n - j0);
        for r in 0..take {
            let orow = &mut out[(i0 + r) * n + j0..(i0 + r) * n + j0 + jtake];
            let srow = &scratch[r * nr..r * nr + jtake];
            if add {
                for (o, &s) in orow.iter_mut().zip(srow) {
                    *o += s;
                }
            } else {
                orow.copy_from_slice(srow);
            }
        }
    }
}

/// Compute `rows` output rows starting at logical row `row0` into the
/// `rows × pb.n` band `out` (`add = true` accumulates onto existing band
/// contents in a single per-element add — the fused `W' + A·B` path).
///
/// Serial per call: callers provide parallelism by banding rows (the tensor
/// ops) or chunking points (the blocked Lloyd assign). The band/chunk
/// layout never changes results — every element is an independent
/// increasing-k sum.
pub(crate) fn gemm_rows(
    a: ASrc<'_>,
    row0: usize,
    rows: usize,
    pb: &PackedB,
    out: &mut [f32],
    add: bool,
) {
    let t = tile();
    let (mr, nr) = (t.mr, t.nr);
    let n = pb.n;
    let kdim = pb.k;
    debug_assert_eq!(out.len(), rows * n);
    if rows == 0 || n == 0 {
        return;
    }
    crate::obs::prof::counters::gemm_call(
        crate::obs::prof::counters::GemmEntry::Rows,
        rows,
        kdim,
        n,
    );
    let mut apanel = vec![0.0f32; kdim * mr];
    let mut scratch = vec![0.0f32; mr * nr];
    for i0 in (0..rows).step_by(mr) {
        let take = mr.min(rows - i0);
        pack_a_panel(a, row0 + i0, take, mr, kdim, &mut apanel);
        emit_panel_rows(t, kdim, &apanel, pb, i0, take, out, add, &mut scratch);
    }
}

/// `A` repacked once into `⌈rows/mr⌉` row panels (column-major within each
/// panel, zero-padded past row `rows`) — the serving-time counterpart of
/// [`PackedB`]. A [`crate::infer::CompressedLinear`] packs its R/A/B
/// factors once at build and reuses the panels for every request, paying
/// only the per-call B-side packing of the activations.
pub(crate) struct PackedA {
    data: Vec<f32>,
    mr: usize,
    kdim: usize,
    rows: usize,
}

impl PackedA {
    fn panel(&self, p: usize) -> &[f32] {
        let len = self.kdim * self.mr;
        &self.data[p * len..(p + 1) * len]
    }

    pub(crate) fn rows(&self) -> usize {
        self.rows
    }

    pub(crate) fn kdim(&self) -> usize {
        self.kdim
    }
}

/// Pack a full `rows × kdim` left operand into [`PackedA`] panels.
/// Disjoint writes into pre-assigned panel slots — identical at any thread
/// count, and each panel's contents are exactly what [`gemm_rows`] would
/// have packed on the fly for the same rows.
pub(crate) fn pack_a(a: ASrc<'_>, rows: usize, kdim: usize, exec: ExecConfig) -> PackedA {
    let mr = tile().mr;
    if rows == 0 || kdim == 0 {
        return PackedA { data: Vec::new(), mr, kdim, rows };
    }
    let np = rows.div_ceil(mr);
    let mut data = vec![0.0f32; np * kdim * mr];
    let exec = if rows * kdim < PACK_PARALLEL_ELEMS { ExecConfig::serial() } else { exec };
    let plen = kdim * mr;
    exec::for_row_bands(exec, &mut data, np, plen, PACK_PANELS_PER_CHUNK, |p0, band| {
        for (pi, panel) in band.chunks_exact_mut(plen).enumerate() {
            let row0 = (p0 + pi) * mr;
            let take = mr.min(rows - row0);
            pack_a_panel(a, row0, take, mr, kdim, panel);
        }
    });
    PackedA { data, mr, kdim, rows }
}

/// [`gemm_rows`] with the A panels supplied pre-packed. `row0` must start
/// on an MR panel boundary (the executor's 64-row bands always do — 64 is
/// a multiple of every supported MR). Bitwise identical to packing the
/// same rows on the fly: the panels hold the same values and the emit path
/// is shared code.
pub(crate) fn gemm_rows_prepacked(
    pa: &PackedA,
    row0: usize,
    rows: usize,
    pb: &PackedB,
    out: &mut [f32],
    add: bool,
) {
    let t = tile();
    let n = pb.n;
    debug_assert_eq!(pa.mr, t.mr, "PackedA built under a different tile");
    debug_assert_eq!(pa.kdim, pb.k, "prepacked GEMM inner dims disagree");
    debug_assert_eq!(out.len(), rows * n);
    if rows == 0 || n == 0 {
        return;
    }
    crate::obs::prof::counters::gemm_call(
        crate::obs::prof::counters::GemmEntry::RowsPrepacked,
        rows,
        pa.kdim,
        n,
    );
    assert_eq!(row0 % pa.mr, 0, "prepacked band must start on an MR boundary");
    assert!(row0 + rows <= pa.rows, "prepacked band past packed rows");
    let mut scratch = vec![0.0f32; pa.mr * t.nr];
    for i0 in (0..rows).step_by(pa.mr) {
        let take = pa.mr.min(rows - i0);
        let panel = pa.panel((row0 + i0) / pa.mr);
        emit_panel_rows(t, pa.kdim, panel, pb, i0, take, out, add, &mut scratch);
    }
}

/// A grouped-int8 right operand repacked into `⌈n/nr⌉` column panels:
/// u8 codes laid out exactly like [`PackedB`]'s f32 lanes (`k × nr` per
/// panel, code 0 past column `n`) plus per-panel `ngroups × nr` f32
/// scale/zero rows (0.0 in pad lanes — the dequantized pad value is
/// `(0 − 0)·0 = 0` and is never copied out anyway). Weight-side only:
/// packed once per model (lazily, like the f32 weight panels) and
/// Arc-shared across requests at ~¼ the footprint.
pub(crate) struct PackedBQ {
    codes: Vec<u8>,
    /// Per-panel per-group scale lanes, `npanels × ngroups × nr`.
    scales: Vec<f32>,
    /// Per-panel per-group zero-point lanes, same layout.
    zeros: Vec<f32>,
    k: usize,
    n: usize,
    nr: usize,
    group: usize,
}

impl PackedBQ {
    fn npanels(&self) -> usize {
        self.n.div_ceil(self.nr)
    }

    fn ngroups(&self) -> usize {
        self.k.div_ceil(self.group)
    }

    fn panel_codes(&self, p: usize) -> &[u8] {
        &self.codes[p * self.k * self.nr..(p + 1) * self.k * self.nr]
    }

    fn panel_scales(&self, p: usize) -> &[f32] {
        let len = self.ngroups() * self.nr;
        &self.scales[p * len..(p + 1) * len]
    }

    fn panel_zeros(&self, p: usize) -> &[f32] {
        let len = self.ngroups() * self.nr;
        &self.zeros[p * len..(p + 1) * len]
    }

    pub(crate) fn kdim(&self) -> usize {
        self.k
    }

    pub(crate) fn ncols(&self) -> usize {
        self.n
    }

    /// Bytes the packed panels occupy (codes + scale/zero metadata) —
    /// compare with the f32 twin's [`PackedB::footprint_bytes`].
    pub(crate) fn footprint_bytes(&self) -> usize {
        self.codes.len() + (self.scales.len() + self.zeros.len()) * std::mem::size_of::<f32>()
    }
}

/// Pack a grouped-int8 `k × n` right operand (row-major u8 `codes`,
/// row-major `⌈k/group⌉ × n` `scales`/`zeros` — the
/// [`crate::quant::QuantizedTensor`] layout) into [`PackedBQ`] panels.
/// Disjoint writes into pre-assigned panel slots — identical at any
/// thread count. The scale/zero lanes are packed serially: they are
/// `group×` smaller than the codes and this runs once per model.
pub(crate) fn pack_bq(
    codes: &[u8],
    scales: &[f32],
    zeros: &[f32],
    k: usize,
    n: usize,
    group: usize,
    exec: ExecConfig,
) -> PackedBQ {
    assert!(group > 0, "quantization group must be positive");
    let nr = tile().nr;
    if k == 0 || n == 0 {
        let (codes, scales, zeros) = (Vec::new(), Vec::new(), Vec::new());
        return PackedBQ { codes, scales, zeros, k, n, nr, group };
    }
    let np = n.div_ceil(nr);
    let ng = k.div_ceil(group);
    debug_assert_eq!(codes.len(), k * n);
    debug_assert_eq!(scales.len(), ng * n);
    debug_assert_eq!(zeros.len(), ng * n);
    let mut cdata = vec![0u8; np * k * nr];
    let exec = if k * n < PACK_PARALLEL_ELEMS { ExecConfig::serial() } else { exec };
    exec::for_row_bands(exec, &mut cdata, np, k * nr, PACK_PANELS_PER_CHUNK, |p0, band| {
        let pcount = band.len() / (k * nr);
        for pi in 0..pcount {
            let p = p0 + pi;
            let j0 = p * nr;
            let jtake = nr.min(n - j0);
            let panel = &mut band[pi * k * nr..(pi + 1) * k * nr];
            for kk in 0..k {
                let src = &codes[kk * n + j0..kk * n + j0 + jtake];
                panel[kk * nr..kk * nr + jtake].copy_from_slice(src);
            }
        }
    });
    let mut sdata = vec![0.0f32; np * ng * nr];
    let mut zdata = vec![0.0f32; np * ng * nr];
    for p in 0..np {
        let j0 = p * nr;
        let jtake = nr.min(n - j0);
        for g in 0..ng {
            let dst = p * ng * nr + g * nr;
            sdata[dst..dst + jtake].copy_from_slice(&scales[g * n + j0..g * n + j0 + jtake]);
            zdata[dst..dst + jtake].copy_from_slice(&zeros[g * n + j0..g * n + j0 + jtake]);
        }
    }
    PackedBQ { codes: cdata, scales: sdata, zeros: zdata, k, n, nr, group }
}

/// The fused dequantize-in-register microkernel. Identical accumulation
/// to [`micro_body`] — one scalar accumulator per element over strictly
/// increasing `kk`, mul then add — with the B row materialized in a local
/// `[f32; NR]` from the u8 codes via [`dequant_u8`] first. The scale and
/// zero lanes are hoisted per group block, so the inner loop touches one
/// u8 row where the f32 kernel touched four bytes per lane.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn micro_body_q<const MR: usize, const NR: usize>(
    kdim: usize,
    group: usize,
    ap: &[f32],
    qp: &[u8],
    sp: &[f32],
    zp: &[f32],
    out: &mut [f32],
) {
    debug_assert!(ap.len() >= kdim * MR);
    debug_assert!(qp.len() >= kdim * NR);
    debug_assert!(sp.len() >= kdim.div_ceil(group) * NR);
    debug_assert!(out.len() >= MR * NR);
    let mut acc = [[0.0f32; NR]; MR];
    let mut kk = 0usize;
    let mut g = 0usize;
    while kk < kdim {
        // Group boundaries are multiples of `group`, so `kk` enters each
        // block aligned and the scale/zero lanes hold for `kend - kk` rows.
        let kend = (kk + group).min(kdim);
        let srow: &[f32; NR] = (&sp[g * NR..g * NR + NR]).try_into().unwrap();
        let zrow: &[f32; NR] = (&zp[g * NR..g * NR + NR]).try_into().unwrap();
        while kk < kend {
            let arow: &[f32; MR] = (&ap[kk * MR..kk * MR + MR]).try_into().unwrap();
            let qrow: &[u8; NR] = (&qp[kk * NR..kk * NR + NR]).try_into().unwrap();
            let mut brow = [0.0f32; NR];
            for j in 0..NR {
                brow[j] = dequant_u8(qrow[j], srow[j], zrow[j]);
            }
            for i in 0..MR {
                let aik = arow[i];
                for j in 0..NR {
                    acc[i][j] += aik * brow[j];
                }
            }
            kk += 1;
        }
        g += 1;
    }
    for i in 0..MR {
        for j in 0..NR {
            out[i * NR + j] = acc[i][j];
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod simd_q {
    use super::micro_body_q;
    use std::sync::OnceLock;

    fn avx2() -> bool {
        static AVX2: OnceLock<bool> = OnceLock::new();
        *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
    }

    // Same wrapper scheme as `simd`: the generic fused body inlines into
    // an AVX2-codegen function so the dequant + j loops vectorize (u8 →
    // f32 widening is a vpmovzxbd + vcvtdq2ps pair at ymm width). No
    // fast-math — arithmetic is bit-identical to the fallback body.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn body_q_8x8(
        kdim: usize,
        group: usize,
        ap: &[f32],
        qp: &[u8],
        sp: &[f32],
        zp: &[f32],
        out: &mut [f32],
    ) {
        micro_body_q::<8, 8>(kdim, group, ap, qp, sp, zp, out)
    }

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn body_q_4x16(
        kdim: usize,
        group: usize,
        ap: &[f32],
        qp: &[u8],
        sp: &[f32],
        zp: &[f32],
        out: &mut [f32],
    ) {
        micro_body_q::<4, 16>(kdim, group, ap, qp, sp, zp, out)
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) fn micro_q_8x8(
        kdim: usize,
        group: usize,
        ap: &[f32],
        qp: &[u8],
        sp: &[f32],
        zp: &[f32],
        out: &mut [f32],
    ) -> bool {
        if !avx2() {
            return false;
        }
        // SAFETY: AVX2 support verified at runtime above.
        unsafe { body_q_8x8(kdim, group, ap, qp, sp, zp, out) };
        true
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) fn micro_q_4x16(
        kdim: usize,
        group: usize,
        ap: &[f32],
        qp: &[u8],
        sp: &[f32],
        zp: &[f32],
        out: &mut [f32],
    ) -> bool {
        if !avx2() {
            return false;
        }
        // SAFETY: AVX2 support verified at runtime above.
        unsafe { body_q_4x16(kdim, group, ap, qp, sp, zp, out) };
        true
    }
}

#[cfg(not(target_arch = "x86_64"))]
mod simd_q {
    #[allow(clippy::too_many_arguments)]
    pub(super) fn micro_q_8x8(
        _: usize,
        _: usize,
        _: &[f32],
        _: &[u8],
        _: &[f32],
        _: &[f32],
        _: &mut [f32],
    ) -> bool {
        false
    }

    #[allow(clippy::too_many_arguments)]
    pub(super) fn micro_q_4x16(
        _: usize,
        _: usize,
        _: &[f32],
        _: &[u8],
        _: &[f32],
        _: &[f32],
        _: &mut [f32],
    ) -> bool {
        false
    }
}

fn run_micro_q(
    t: Tile,
    kdim: usize,
    group: usize,
    ap: &[f32],
    qp: &[u8],
    sp: &[f32],
    zp: &[f32],
    out: &mut [f32],
) {
    match (t.mr, t.nr) {
        (8, 8) => {
            if !simd_q::micro_q_8x8(kdim, group, ap, qp, sp, zp, out) {
                micro_body_q::<8, 8>(kdim, group, ap, qp, sp, zp, out);
            }
        }
        (4, 16) => {
            if !simd_q::micro_q_4x16(kdim, group, ap, qp, sp, zp, out) {
                micro_body_q::<4, 16>(kdim, group, ap, qp, sp, zp, out);
            }
        }
        _ => unreachable!("unsupported GEMM tile {t:?}"),
    }
}

/// [`emit_panel_rows`] for quantized B panels: one packed A panel driven
/// across every [`PackedBQ`] panel through the fused microkernel. Same
/// output copy/accumulate tail, so banding and add-mode semantics are
/// identical to the f32 path.
#[allow(clippy::too_many_arguments)]
fn emit_panel_rows_q(
    t: Tile,
    apanel: &[f32],
    pbq: &PackedBQ,
    i0: usize,
    take: usize,
    out: &mut [f32],
    add: bool,
    scratch: &mut [f32],
) {
    let nr = t.nr;
    let n = pbq.n;
    for p in 0..pbq.npanels() {
        run_micro_q(
            t,
            pbq.k,
            pbq.group,
            apanel,
            pbq.panel_codes(p),
            pbq.panel_scales(p),
            pbq.panel_zeros(p),
            scratch,
        );
        let j0 = p * nr;
        let jtake = nr.min(n - j0);
        for r in 0..take {
            let orow = &mut out[(i0 + r) * n + j0..(i0 + r) * n + j0 + jtake];
            let srow = &scratch[r * nr..r * nr + jtake];
            if add {
                for (o, &s) in orow.iter_mut().zip(srow) {
                    *o += s;
                }
            } else {
                orow.copy_from_slice(srow);
            }
        }
    }
}

/// [`gemm_rows`] against a quantized right operand: packs A panels on
/// the fly and serves them through the fused dequantize microkernel.
/// Bitwise equal to dequantizing the operand and calling [`gemm_rows`].
pub(crate) fn gemm_rows_q(
    a: ASrc<'_>,
    row0: usize,
    rows: usize,
    pbq: &PackedBQ,
    out: &mut [f32],
    add: bool,
) {
    let t = tile();
    let (mr, nr) = (t.mr, t.nr);
    let n = pbq.n;
    let kdim = pbq.k;
    debug_assert_eq!(out.len(), rows * n);
    if rows == 0 || n == 0 {
        return;
    }
    crate::obs::prof::counters::gemm_call(
        crate::obs::prof::counters::GemmEntry::RowsQ,
        rows,
        kdim,
        n,
    );
    let mut apanel = vec![0.0f32; kdim * mr];
    let mut scratch = vec![0.0f32; mr * nr];
    for i0 in (0..rows).step_by(mr) {
        let take = mr.min(rows - i0);
        pack_a_panel(a, row0 + i0, take, mr, kdim, &mut apanel);
        emit_panel_rows_q(t, &apanel, pbq, i0, take, out, add, &mut scratch);
    }
}

/// [`gemm_rows_prepacked`] against a quantized right operand. `row0`
/// must start on an MR panel boundary, as in the f32 twin.
pub(crate) fn gemm_rows_q_prepacked(
    pa: &PackedA,
    row0: usize,
    rows: usize,
    pbq: &PackedBQ,
    out: &mut [f32],
    add: bool,
) {
    let t = tile();
    let n = pbq.n;
    debug_assert_eq!(pa.mr, t.mr, "PackedA built under a different tile");
    debug_assert_eq!(pa.kdim, pbq.k, "prepacked GEMM inner dims disagree");
    debug_assert_eq!(out.len(), rows * n);
    if rows == 0 || n == 0 {
        return;
    }
    crate::obs::prof::counters::gemm_call(
        crate::obs::prof::counters::GemmEntry::RowsQPrepacked,
        rows,
        pa.kdim,
        n,
    );
    assert_eq!(row0 % pa.mr, 0, "prepacked band must start on an MR boundary");
    assert!(row0 + rows <= pa.rows, "prepacked band past packed rows");
    let mut scratch = vec![0.0f32; pa.mr * t.nr];
    for i0 in (0..rows).step_by(pa.mr) {
        let take = pa.mr.min(rows - i0);
        let panel = pa.panel((row0 + i0) / pa.mr);
        emit_panel_rows_q(t, panel, pbq, i0, take, out, add, &mut scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// The reference order: one scalar accumulator per element, k increasing.
    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn packed(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let pb = pack_b(b, k, n, ExecConfig::serial());
        let mut out = vec![0.0f32; m * n];
        gemm_rows(ASrc::Rows { data: a, k }, 0, m, &pb, &mut out, false);
        out
    }

    fn randv(len: usize, rng: &mut Rng) -> Vec<f32> {
        (0..len).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn tile_is_supported_shape() {
        let t = tile();
        assert!(matches!((t.mr, t.nr), (8, 8) | (4, 16)), "tile {t:?}");
    }

    // NOTE: there is deliberately no test asserting the value of the
    // process-wide kernel flag — lib tests run concurrently and another
    // test flipping it (e.g. the ops.rs kernel-interchangeability test)
    // would make such an assertion flaky. Kernel selection is covered
    // behaviorally: outputs are bitwise identical under both kernels, which
    // is what the interchangeability tests pin.

    /// The ISSUE 3 exact-shape property: every MR remainder (m sweeps two
    /// full panels plus one) × every NR remainder (n likewise) × ragged k,
    /// packed output bitwise equal to the naive increasing-k sum.
    #[test]
    fn packed_matches_naive_bitwise_all_tile_remainders() {
        let mut rng = Rng::new(600);
        let t = tile();
        for m in 1..=(2 * t.mr + 1) {
            for n in 1..=(2 * t.nr + 1) {
                for &k in &[1usize, 3, 64] {
                    let a = randv(m * k, &mut rng);
                    let b = randv(k * n, &mut rng);
                    assert_eq!(
                        bits(&packed(&a, &b, m, k, n)),
                        bits(&naive(&a, &b, m, k, n)),
                        "m={m} n={n} k={k}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_matches_naive_bitwise_large_ragged() {
        let mut rng = Rng::new(601);
        for &(m, k, n) in &[
            (63usize, 130usize, 65usize),
            (130, 127, 129),
            (128, 64, 128),
            (1, 130, 130),
            (130, 1, 1),
        ] {
            let a = randv(m * k, &mut rng);
            let b = randv(k * n, &mut rng);
            assert_eq!(
                bits(&packed(&a, &b, m, k, n)),
                bits(&naive(&a, &b, m, k, n)),
                "m={m} n={n} k={k}"
            );
        }
    }

    /// Strided-A packing (the t_matmul path): logical A is m × k but stored
    /// as a row-major k × m buffer. Must still equal the naive sum bitwise.
    #[test]
    fn strided_a_packing_matches_naive_bitwise() {
        let mut rng = Rng::new(602);
        for &(kdim, m, n) in &[(35usize, 67usize, 19usize), (130, 63, 17), (64, 128, 31)] {
            let at = randv(kdim * m, &mut rng); // k × m source
            let b = randv(kdim * n, &mut rng);
            let pb = pack_b(&b, kdim, n, ExecConfig::serial());
            let mut got = vec![0.0f32; m * n];
            gemm_rows(ASrc::Cols { data: &at, ld: m }, 0, m, &pb, &mut got, false);
            let mut want = vec![0.0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0f32;
                    for kk in 0..kdim {
                        s += at[kk * m + i] * b[kk * n + j];
                    }
                    want[i * n + j] = s;
                }
            }
            assert_eq!(bits(&got), bits(&want), "kdim={kdim} m={m} n={n}");
        }
    }

    /// `add = true` folds the product onto existing contents with a single
    /// per-element add — exactly `prefill + (full register sum)`.
    #[test]
    fn add_mode_is_single_fused_add() {
        let mut rng = Rng::new(603);
        let (m, k, n) = (13usize, 37usize, 11usize);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let prefill = randv(m * n, &mut rng);
        let pb = pack_b(&b, k, n, ExecConfig::serial());
        let mut got = prefill.clone();
        gemm_rows(ASrc::Rows { data: &a, k }, 0, m, &pb, &mut got, true);
        let prod = naive(&a, &b, m, k, n);
        let want: Vec<f32> = prefill.iter().zip(&prod).map(|(&w, &p)| w + p).collect();
        assert_eq!(bits(&got), bits(&want));
    }

    /// Band splits (the executor's unit of parallelism) never change bits:
    /// computing rows in two separate gemm_rows calls equals one full call.
    #[test]
    fn row_offset_bands_match_full_run_bitwise() {
        let mut rng = Rng::new(604);
        let (m, k, n) = (29usize, 45usize, 23usize);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let pb = pack_b(&b, k, n, ExecConfig::serial());
        let mut full = vec![0.0f32; m * n];
        gemm_rows(ASrc::Rows { data: &a, k }, 0, m, &pb, &mut full, false);
        for split in [1usize, 5, 8, 16, 28] {
            let mut banded = vec![0.0f32; m * n];
            let (head, tail) = banded.split_at_mut(split * n);
            gemm_rows(ASrc::Rows { data: &a, k }, 0, split, &pb, head, false);
            gemm_rows(ASrc::Rows { data: &a, k }, split, m - split, &pb, tail, false);
            assert_eq!(bits(&banded), bits(&full), "split at {split}");
        }
    }

    #[test]
    fn degenerate_shapes_are_fine() {
        // k = 0: product of an m×0 and 0×n operand is all zeros.
        let pb = pack_b(&[], 0, 7, ExecConfig::serial());
        let mut out = vec![1.0f32; 3 * 7];
        gemm_rows(ASrc::Rows { data: &[], k: 0 }, 0, 3, &pb, &mut out, false);
        assert!(out.iter().all(|&v| v == 0.0));
        // n = 0 / rows = 0: no-ops.
        let pb0 = pack_b(&[], 5, 0, ExecConfig::serial());
        assert_eq!(pb0.n, 0);
        let mut empty: Vec<f32> = Vec::new();
        gemm_rows(ASrc::Rows { data: &[0.0; 10], k: 5 }, 0, 2, &pb0, &mut empty, false);
        gemm_rows(ASrc::Rows { data: &[], k: 5 }, 0, 0, &pb0, &mut empty, false);
    }

    /// Pre-packed A panels are bit-for-bit the on-the-fly path: same
    /// panels, same microkernel calls. Sweeps ragged MR remainders, both
    /// A sources, and add mode.
    #[test]
    fn prepacked_matches_on_the_fly_bitwise() {
        let mut rng = Rng::new(606);
        for &(m, k, n) in &[(2 * 64 + 13usize, 45usize, 33usize), (64, 130, 17), (7, 3, 70)] {
            let a = randv(m * k, &mut rng);
            let at = randv(k * m, &mut rng); // k × m strided source
            let b = randv(k * n, &mut rng);
            let pb = pack_b(&b, k, n, ExecConfig::serial());
            for add in [false, true] {
                let prefill = randv(m * n, &mut rng);

                let mut want = prefill.clone();
                gemm_rows(ASrc::Rows { data: &a, k }, 0, m, &pb, &mut want, add);
                let pa = pack_a(ASrc::Rows { data: &a, k }, m, k, ExecConfig::serial());
                let mut got = prefill.clone();
                gemm_rows_prepacked(&pa, 0, m, &pb, &mut got, add);
                assert_eq!(bits(&got), bits(&want), "rows m={m} k={k} n={n} add={add}");

                let mut want_t = prefill.clone();
                gemm_rows(ASrc::Cols { data: &at, ld: m }, 0, m, &pb, &mut want_t, add);
                let pa_t = pack_a(ASrc::Cols { data: &at, ld: m }, m, k, ExecConfig::serial());
                let mut got_t = prefill.clone();
                gemm_rows_prepacked(&pa_t, 0, m, &pb, &mut got_t, add);
                assert_eq!(bits(&got_t), bits(&want_t), "cols m={m} k={k} n={n} add={add}");
            }
        }
        // Band splits at the executor's 64-row granularity (multiples of
        // every supported MR) match the full run.
        let (m, k, n) = (3 * 64 + 9usize, 37usize, 29usize);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let pb = pack_b(&b, k, n, ExecConfig::serial());
        let pa = pack_a(ASrc::Rows { data: &a, k }, m, k, ExecConfig::serial());
        let mut full = vec![0.0f32; m * n];
        gemm_rows_prepacked(&pa, 0, m, &pb, &mut full, false);
        let mut banded = vec![0.0f32; m * n];
        let mut row = 0;
        let mut rest: &mut [f32] = &mut banded;
        while row < m {
            let take = 64.min(m - row);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take * n);
            gemm_rows_prepacked(&pa, row, take, &pb, head, false);
            rest = tail;
            row += take;
        }
        assert_eq!(bits(&banded), bits(&full), "64-row band split");
        assert_eq!(pa.rows(), m);
        assert_eq!(pa.kdim(), k);
    }

    /// Parallel A packing writes the same panels as serial packing.
    #[test]
    fn pack_a_thread_invariant() {
        let mut rng = Rng::new(607);
        // Above PACK_PARALLEL_ELEMS so the parallel path actually runs.
        let (m, k) = (600usize, 130usize);
        let a = randv(m * k, &mut rng);
        let base = pack_a(ASrc::Rows { data: &a, k }, m, k, ExecConfig::serial());
        for threads in [2, 4, 8] {
            let p = pack_a(ASrc::Rows { data: &a, k }, m, k, ExecConfig::with_threads(threads));
            assert_eq!(bits(&p.data), bits(&base.data), "{threads} threads");
        }
    }

    /// Parallel B packing writes the same panels as serial packing.
    #[test]
    fn pack_b_thread_invariant() {
        let mut rng = Rng::new(605);
        // Above PACK_PARALLEL_ELEMS so the parallel path actually runs.
        let (k, n) = (300usize, 260usize);
        let b = randv(k * n, &mut rng);
        let base = pack_b(&b, k, n, ExecConfig::serial());
        for threads in [2, 4, 8] {
            let p = pack_b(&b, k, n, ExecConfig::with_threads(threads));
            assert_eq!(bits(&p.data), bits(&base.data), "{threads} threads");
        }
    }

    use crate::quant::{QuantConfig, QuantizedTensor};
    use crate::tensor::Tensor;

    /// Quantize a k × n right operand and return (panels, dequantized f32
    /// oracle operand) — the pair every fused-path test compares.
    fn quantized_b(k: usize, n: usize, group: usize, rng: &mut Rng) -> (PackedBQ, Vec<f32>) {
        let b = Tensor::randn(&[k, n], rng);
        let q = QuantizedTensor::quantize(&b, &QuantConfig { group });
        let pbq =
            pack_bq(q.data(), q.scales(), q.zeros(), k, n, group, ExecConfig::serial());
        (pbq, q.dequantize().into_vec())
    }

    /// The PR 6 kernel contract: the fused dequantize-in-register path is
    /// **bitwise** equal to dequantizing the operand and running the f32
    /// packed GEMM — over every MR/NR remainder and ragged group sizes
    /// (group 1, non-divisor groups, group > k).
    #[test]
    fn fused_q_matches_dequant_then_f32_bitwise_all_remainders() {
        let mut rng = Rng::new(608);
        let t = tile();
        for m in 1..=(2 * t.mr + 1) {
            for n in 1..=(2 * t.nr + 1) {
                for &k in &[1usize, 3, 64] {
                    for &group in &[1usize, 5, 64, 100] {
                        let a = randv(m * k, &mut rng);
                        let (pbq, bde) = quantized_b(k, n, group, &mut rng);
                        let mut got = vec![0.0f32; m * n];
                        gemm_rows_q(ASrc::Rows { data: &a, k }, 0, m, &pbq, &mut got, false);
                        assert_eq!(
                            bits(&got),
                            bits(&packed(&a, &bde, m, k, n)),
                            "m={m} n={n} k={k} group={group}"
                        );
                    }
                }
            }
        }
    }

    /// Prepacked-A fused GEMM, band splits, add mode, and the strided-A
    /// source all match the on-the-fly fused run bitwise.
    #[test]
    fn fused_q_prepacked_bands_and_add_match_bitwise() {
        let mut rng = Rng::new(609);
        let (m, k, n) = (2 * 64 + 13usize, 45usize, 33usize);
        let a = randv(m * k, &mut rng);
        let at = randv(k * m, &mut rng);
        let (pbq, _) = quantized_b(k, n, 7, &mut rng);
        for add in [false, true] {
            let prefill = randv(m * n, &mut rng);

            let mut want = prefill.clone();
            gemm_rows_q(ASrc::Rows { data: &a, k }, 0, m, &pbq, &mut want, add);
            let pa = pack_a(ASrc::Rows { data: &a, k }, m, k, ExecConfig::serial());
            let mut got = prefill.clone();
            gemm_rows_q_prepacked(&pa, 0, m, &pbq, &mut got, add);
            assert_eq!(bits(&got), bits(&want), "rows add={add}");

            let mut want_t = prefill.clone();
            gemm_rows_q(ASrc::Cols { data: &at, ld: m }, 0, m, &pbq, &mut want_t, add);
            let pa_t = pack_a(ASrc::Cols { data: &at, ld: m }, m, k, ExecConfig::serial());
            let mut got_t = prefill.clone();
            gemm_rows_q_prepacked(&pa_t, 0, m, &pbq, &mut got_t, add);
            assert_eq!(bits(&got_t), bits(&want_t), "cols add={add}");
        }
        // 64-row band splits (the executor's granularity) match a full run.
        let pa = pack_a(ASrc::Rows { data: &a, k }, m, k, ExecConfig::serial());
        let mut full = vec![0.0f32; m * n];
        gemm_rows_q_prepacked(&pa, 0, m, &pbq, &mut full, false);
        let mut banded = vec![0.0f32; m * n];
        let mut row = 0;
        let mut rest: &mut [f32] = &mut banded;
        while row < m {
            let take = 64.min(m - row);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take * n);
            gemm_rows_q_prepacked(&pa, row, take, &pbq, head, false);
            rest = tail;
            row += take;
        }
        assert_eq!(bits(&banded), bits(&full), "64-row band split");
    }

    #[test]
    fn fused_q_degenerate_shapes() {
        // k = 0 product is all zeros; n = 0 / rows = 0 are no-ops.
        let pbq = pack_bq(&[], &[], &[], 0, 7, 64, ExecConfig::serial());
        let mut out = vec![1.0f32; 3 * 7];
        gemm_rows_q(ASrc::Rows { data: &[], k: 0 }, 0, 3, &pbq, &mut out, false);
        assert!(out.iter().all(|&v| v == 0.0));
        let pbq0 = pack_bq(&[], &[], &[], 5, 0, 64, ExecConfig::serial());
        assert_eq!(pbq0.ncols(), 0);
        let mut empty: Vec<f32> = Vec::new();
        gemm_rows_q(ASrc::Rows { data: &[0.0; 10], k: 5 }, 0, 2, &pbq0, &mut empty, false);
    }

    /// Parallel code-panel packing writes the same panels as serial.
    #[test]
    fn pack_bq_thread_invariant() {
        let mut rng = Rng::new(610);
        // Above PACK_PARALLEL_ELEMS so the parallel path actually runs.
        let (k, n, group) = (300usize, 260usize, 32usize);
        let b = Tensor::randn(&[k, n], &mut rng);
        let q = QuantizedTensor::quantize(&b, &QuantConfig { group });
        let base = pack_bq(q.data(), q.scales(), q.zeros(), k, n, group, ExecConfig::serial());
        for threads in [2, 4, 8] {
            let p =
                pack_bq(q.data(), q.scales(), q.zeros(), k, n, group, ExecConfig::with_threads(threads));
            assert_eq!(p.codes, base.codes, "{threads} threads");
            assert_eq!(bits(&p.scales), bits(&base.scales), "{threads} threads");
            assert_eq!(bits(&p.zeros), bits(&base.zeros), "{threads} threads");
        }
    }

    /// The point of the exercise: quantized panels are ~¼ the f32 panel
    /// footprint (codes are 1 byte vs 4, metadata amortized over `group`).
    #[test]
    fn quantized_panels_are_about_4x_smaller() {
        let mut rng = Rng::new(611);
        let (k, n, group) = (512usize, 512usize, 64usize);
        let b = Tensor::randn(&[k, n], &mut rng);
        let q = QuantizedTensor::quantize(&b, &QuantConfig { group });
        let pbq = pack_bq(q.data(), q.scales(), q.zeros(), k, n, group, ExecConfig::serial());
        let pb = pack_b(b.data(), k, n, ExecConfig::serial());
        let ratio = pbq.footprint_bytes() as f64 / pb.footprint_bytes() as f64;
        assert!(ratio < 0.3, "quantized/f32 panel footprint {ratio}");
    }
}
