//! Host-side dense tensors.
//!
//! A deliberately small, dependency-free row-major `f32` tensor with the
//! operations the compression pipeline needs: matmul (packed register-tiled
//! GEMM in [`gemm`], with the old blocked kernel kept as baseline),
//! transpose, column/row views, norms, elementwise combinators. Device
//! tensors live in `runtime::` as PJRT buffers; this type is the host
//! staging format.

pub mod gemm;
mod ops;

pub(crate) use ops::{
    gemm_packed_b_into, gemm_packed_bq_into, gemm_prepacked_bq_into, gemm_prepacked_into,
    matmul_band,
};

use crate::util::rng::Rng;

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Build from explicit shape + data. Panics if sizes disagree.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} wants {n} elements, got {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// Standard-normal random tensor.
    pub fn randn(shape: &[usize], rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    /// Uniform random tensor in `[lo, hi)`.
    pub fn rand(shape: &[usize], lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.uniform_in(lo, hi)).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows for a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2, "rows() on non-matrix");
        self.shape[0]
    }

    /// Number of columns for a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2, "cols() on non-matrix");
        self.shape[1]
    }

    /// Element accessor for 2-D tensors.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert_eq!(self.ndim(), 2);
        &mut self.data[i * self.shape[1] + j]
    }

    /// Borrow row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Copy column `j` of a 2-D tensor into a fresh vector.
    /// Columns are the paper's "channels".
    pub fn col(&self, j: usize) -> Vec<f32> {
        let (r, c) = (self.rows(), self.cols());
        (0..r).map(|i| self.data[i * c + j]).collect()
    }

    /// Overwrite column `j` from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        let (r, c) = (self.rows(), self.cols());
        assert_eq!(v.len(), r);
        for i in 0..r {
            self.data[i * c + j] = v[i];
        }
    }

    /// Reshape without copying. Product of dims must match.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {shape:?}", self.shape);
        self.shape = shape.to_vec();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.col(1), vec![2., 5.]);
    }

    #[test]
    fn set_col_roundtrip() {
        let mut t = Tensor::zeros(&[3, 2]);
        t.set_col(1, &[7., 8., 9.]);
        assert_eq!(t.col(1), vec![7., 8., 9.]);
        assert_eq!(t.col(0), vec![0., 0., 0.]);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn from_vec_size_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn randn_is_deterministic() {
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        assert_eq!(Tensor::randn(&[4, 4], &mut r1), Tensor::randn(&[4, 4], &mut r2));
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).reshape(&[3, 2]);
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.at(2, 1), 6.0);
    }
}
