//! Tensor operations: matmul, transpose, norms, elementwise.
//!
//! The matmul here is the L3 CPU hot path for compression-time work (SVD
//! subspace iteration, k-means distance blocks). Since PR 3 it is the
//! packed register-tiled GEMM engine in [`super::gemm`] — B packed into
//! SIMD-width column panels, A into row panels (strided packing for
//! `t_matmul`, so `AᵀQ` never materializes a transpose), an MR×NR
//! register-accumulator microkernel. The pre-PR-3 cache-blocked i-k-j
//! kernel ([`matmul_band`]) survives as [`gemm::GemmKernel::Blocked`] —
//! bench baseline and cross-check oracle, selected with
//! `SWSC_GEMM_KERNEL=blocked`. Both kernels accumulate each output element
//! in a single f32 register over increasing k, so they are bit-identical
//! to each other and to the naive triple loop at every thread count. The
//! model's own matmuls run inside XLA, not here.

use super::{gemm, Tensor};
use crate::exec::{self, ExecConfig};

/// Cache block edge for the matmul microkernel (f32: 64·64·4 B = 16 KiB per
/// operand block, comfortably inside L1/L2). Also the row-band granularity
/// handed to the executor: output rows are independent, so any banding is
/// bit-identical to the serial kernel.
const BLOCK: usize = 64;

/// Below this many multiply-adds a matmul runs inline serial. The floor is
/// backend-dependent: the persistent pool dispatches a batch in ~µs, so it
/// profitably parallelizes matmuls (e.g. the 2¹⁸-MAC k-means cross terms of
/// a 128² compression job) that would be swamped by the tens-of-µs
/// per-worker latency of spawn-per-call. The packed kernel retires MACs
/// roughly twice as fast as the blocked one (no per-MAC accumulator
/// load/store), so its pool floor is one notch higher to keep the same
/// dispatch-cost amortization. Thresholds only pick the thread count,
/// never the chunk layout, so they cannot affect numerics.
const MIN_PARALLEL_MACS_POOL_PACKED: usize = 1 << 19;
const MIN_PARALLEL_MACS_POOL: usize = 1 << 18;
const MIN_PARALLEL_MACS_SPAWN: usize = 1 << 21;

/// Below this many elements a transpose runs inline serial (pure copy —
/// memory-bound, so the bar is higher per element than for matmul).
const MIN_PARALLEL_ELEMS_POOL: usize = 1 << 16;
const MIN_PARALLEL_ELEMS_SPAWN: usize = 1 << 17;

pub(crate) fn min_parallel_macs() -> usize {
    match (exec::backend(), gemm::kernel()) {
        (exec::ExecBackend::Pool, gemm::GemmKernel::Packed) => MIN_PARALLEL_MACS_POOL_PACKED,
        (exec::ExecBackend::Pool, gemm::GemmKernel::Blocked) => MIN_PARALLEL_MACS_POOL,
        (exec::ExecBackend::SpawnPerCall, _) => MIN_PARALLEL_MACS_SPAWN,
    }
}

fn min_parallel_elems() -> usize {
    match exec::backend() {
        exec::ExecBackend::Pool => MIN_PARALLEL_ELEMS_POOL,
        exec::ExecBackend::SpawnPerCall => MIN_PARALLEL_ELEMS_SPAWN,
    }
}

/// One row band of the blocked i-k-j kernel: computes output rows
/// `first_row..first_row + band.len()/n` into the disjoint band slice. The
/// per-row accumulation order (kb → jb → kk → j) visits every k exactly
/// once in increasing order per element, so banding never changes a bit of
/// the result — and the packed engine in [`super::gemm`] matches it
/// bitwise for the same reason.
///
/// Since PR 3 this is the [`gemm::GemmKernel::Blocked`] baseline: the
/// default path routes through the packed engine, and this kernel remains
/// as the bench comparison (`packed_vs_blocked_*`) and as the fallback the
/// blocked Lloyd assign uses under `SWSC_GEMM_KERNEL=blocked`.
pub(crate) fn matmul_band(a: &[f32], b: &[f32], k: usize, n: usize, first_row: usize, band: &mut [f32]) {
    if n == 0 {
        return;
    }
    let rows = band.len() / n;
    for kb in (0..k).step_by(BLOCK) {
        let kmax = (kb + BLOCK).min(k);
        for jb in (0..n).step_by(BLOCK) {
            let jmax = (jb + BLOCK).min(n);
            for r in 0..rows {
                let arow = &a[(first_row + r) * k..(first_row + r + 1) * k];
                let orow = &mut band[r * n..(r + 1) * n];
                for kk in kb..kmax {
                    // No zero-skip here: on dense weights a per-element
                    // branch in the hot loop defeats vectorization and the
                    // mispredict costs more than the multiply it saves.
                    // Sparsity-aware paths belong in a dedicated kernel.
                    let aik = arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    // Innermost j loop: contiguous, auto-vectorizes.
                    for j in jb..jmax {
                        orow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
}

/// Shared band dispatch for every GEMM entry point (`matmul`, the strided
/// `t_matmul`, the fused `matmul_add_assign`): serial-threshold downgrade,
/// kernel selection, B packing, and row-band parallelism live here exactly
/// once. `out` is the `m × n` destination; `add = true` folds the product
/// onto its contents with a single per-element add.
fn gemm_into(
    a: gemm::ASrc<'_>,
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    add: bool,
    exec: ExecConfig,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let exec = if m * n * k < min_parallel_macs() { ExecConfig::serial() } else { exec };
    if gemm::kernel() == gemm::GemmKernel::Blocked {
        if let gemm::ASrc::Rows { data: araw, .. } = a {
            exec::for_row_bands(exec, out, m, n, BLOCK, |first_row, band| {
                if add {
                    // Oracle route for the fused add: band product computed
                    // separately, then folded with one add — same single-add
                    // rounding as the packed path.
                    let mut tmp = vec![0.0f32; band.len()];
                    matmul_band(araw, b, k, n, first_row, &mut tmp);
                    for (o, &v) in band.iter_mut().zip(&tmp) {
                        *o += v;
                    }
                } else {
                    matmul_band(araw, b, k, n, first_row, band);
                }
            });
            return;
        }
        // ASrc::Cols under the blocked kernel is only reachable if the
        // process-wide kernel flips mid-call (t_matmul routes through the
        // transpose before getting here) — the packed path below is
        // bit-identical, so just fall through.
    }
    let pb = gemm::pack_b(b, k, n, exec);
    gemm_packed_b_into(a, &pb, m, add, exec, out);
}

/// Band dispatch over a pre-packed B operand: the tail of [`gemm_into`],
/// shared with the compressed-inference paths in [`crate::infer`] that
/// reuse one [`gemm::PackedB`] across many calls (e.g. a weight factor
/// consumed as the right operand of every request). Always the packed
/// engine — the blocked kernel is bitwise identical, so the
/// `SWSC_GEMM_KERNEL` bench knob deliberately does not reach this path.
pub(crate) fn gemm_packed_b_into(
    a: gemm::ASrc<'_>,
    pb: &gemm::PackedB,
    m: usize,
    add: bool,
    exec: ExecConfig,
    out: &mut [f32],
) {
    let (k, n) = (pb.kdim(), pb.ncols());
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let exec = if m * n * k < min_parallel_macs() { ExecConfig::serial() } else { exec };
    exec::for_row_bands(exec, out, m, n, BLOCK, |first_row, band| {
        gemm::gemm_rows(a, first_row, band.len() / n, pb, band, add);
    });
}

/// Like [`gemm_packed_b_into`] with the A panels *also* pre-packed — the
/// compressed-inference hot path: a [`crate::infer::CompressedLinear`]
/// packs its R/A/B factors once at build and every request pays only the
/// per-call activation packing. Bitwise identical to packing A on the fly
/// (the panels hold the same values; [`BLOCK`] bands start on MR panel
/// boundaries by construction).
pub(crate) fn gemm_prepacked_into(
    pa: &gemm::PackedA,
    pb: &gemm::PackedB,
    add: bool,
    exec: ExecConfig,
    out: &mut [f32],
) {
    let (m, n) = (pa.rows(), pb.ncols());
    debug_assert_eq!(pa.kdim(), pb.kdim(), "prepacked GEMM inner dims disagree");
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let exec = if m * n * pb.kdim() < min_parallel_macs() { ExecConfig::serial() } else { exec };
    exec::for_row_bands(exec, out, m, n, BLOCK, |first_row, band| {
        gemm::gemm_rows_prepacked(pa, first_row, band.len() / n, pb, band, add);
    });
}

/// [`gemm_packed_b_into`] against a quantized right operand
/// ([`gemm::PackedBQ`]): same band dispatch and serial-downgrade
/// threshold, with the fused dequantize-in-register kernel inside.
/// Bitwise equal to dequantizing the operand and calling the f32 twin,
/// at any thread count.
pub(crate) fn gemm_packed_bq_into(
    a: gemm::ASrc<'_>,
    pbq: &gemm::PackedBQ,
    m: usize,
    add: bool,
    exec: ExecConfig,
    out: &mut [f32],
) {
    let (k, n) = (pbq.kdim(), pbq.ncols());
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let exec = if m * n * k < min_parallel_macs() { ExecConfig::serial() } else { exec };
    exec::for_row_bands(exec, out, m, n, BLOCK, |first_row, band| {
        gemm::gemm_rows_q(a, first_row, band.len() / n, pbq, band, add);
    });
}

/// [`gemm_prepacked_into`] against a quantized right operand — the
/// quantized serving hot path: activations prepacked once per request,
/// weight codes + scales streamed through the fused microkernel.
pub(crate) fn gemm_prepacked_bq_into(
    pa: &gemm::PackedA,
    pbq: &gemm::PackedBQ,
    add: bool,
    exec: ExecConfig,
    out: &mut [f32],
) {
    let (m, n) = (pa.rows(), pbq.ncols());
    debug_assert_eq!(pa.kdim(), pbq.kdim(), "prepacked GEMM inner dims disagree");
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let exec = if m * n * pbq.kdim() < min_parallel_macs() { ExecConfig::serial() } else { exec };
    exec::for_row_bands(exec, out, m, n, BLOCK, |first_row, band| {
        gemm::gemm_rows_q_prepacked(pa, first_row, band.len() / n, pbq, band, add);
    });
}

impl Tensor {
    /// Matrix product `self · other` for 2-D tensors, parallelized over row
    /// bands with the process-wide [`exec::global`] config.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        self.matmul_with(other, exec::global())
    }

    /// [`Tensor::matmul`] with an explicit thread config. Output is
    /// bit-identical for every `exec.threads` and for either GEMM kernel.
    pub fn matmul_with(&self, other: &Tensor, exec: ExecConfig) -> Tensor {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (other.rows(), other.cols());
        assert_eq!(k, k2, "matmul inner dim: {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        gemm_into(
            gemm::ASrc::Rows { data: self.data(), k },
            other.data(),
            m,
            k,
            n,
            false,
            exec,
            &mut out,
        );
        Tensor::from_vec(&[m, n], out)
    }

    /// `selfᵀ · other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Tensor) -> Tensor {
        self.t_matmul_with(other, exec::global())
    }

    /// [`Tensor::t_matmul`] with an explicit thread config.
    ///
    /// Under the packed kernel the A panels are packed straight out of the
    /// transposed-stride source (`self` is `k × m` row-major; packing reads
    /// contiguous MR-length runs per k step), so no `m × k` transpose is
    /// ever allocated — the copy the SVD power iteration used to pay on
    /// every `AᵀQ`. The blocked baseline keeps the old
    /// transpose-then-matmul route; both produce identical bits.
    pub fn t_matmul_with(&self, other: &Tensor, exec: ExecConfig) -> Tensor {
        let (kdim, m) = (self.rows(), self.cols());
        let (k2, n) = (other.rows(), other.cols());
        assert_eq!(kdim, k2, "t_matmul inner dim: {kdim} vs {k2}");
        if gemm::kernel() == gemm::GemmKernel::Blocked {
            return self.transpose_with(exec).matmul_with(other, exec);
        }
        let mut out = vec![0.0f32; m * n];
        gemm_into(
            gemm::ASrc::Cols { data: self.data(), ld: m },
            other.data(),
            m,
            kdim,
            n,
            false,
            exec,
            &mut out,
        );
        Tensor::from_vec(&[m, n], out)
    }

    /// Fused `out += self · other` (shapes `m×k · k×n` onto `m×n`),
    /// parallelized like [`Tensor::matmul`]. The product of each element is
    /// fully accumulated in registers and folded onto `out` with a single
    /// add, so the result is bit-identical to `out.add(&self.matmul(other))`
    /// without allocating the intermediate product.
    pub fn matmul_add_assign(&self, other: &Tensor, out: &mut Tensor) {
        self.matmul_add_assign_with(other, out, exec::global())
    }

    /// [`Tensor::matmul_add_assign`] with an explicit thread config.
    pub fn matmul_add_assign_with(&self, other: &Tensor, out: &mut Tensor, exec: ExecConfig) {
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (other.rows(), other.cols());
        assert_eq!(k, k2, "matmul inner dim: {k} vs {k2}");
        assert_eq!(out.shape(), &[m, n], "matmul_add_assign output shape");
        gemm_into(
            gemm::ASrc::Rows { data: self.data(), k },
            other.data(),
            m,
            k,
            n,
            true,
            exec,
            out.data_mut(),
        );
    }

    /// Transposed copy of a 2-D tensor.
    pub fn transpose(&self) -> Tensor {
        self.transpose_with(exec::global())
    }

    /// [`Tensor::transpose`] with an explicit thread config. Pure disjoint
    /// writes — trivially bit-identical at any thread count.
    pub fn transpose_with(&self, exec: ExecConfig) -> Tensor {
        let (r, c) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; r * c];
        if r == 0 || c == 0 {
            return Tensor::from_vec(&[c, r], out);
        }
        let exec = if r * c < min_parallel_elems() { ExecConfig::serial() } else { exec };
        let src = self.data();
        // Band over output rows (input columns); blocked inner loops keep
        // the cache behavior of the serial version.
        exec::for_row_bands(exec, &mut out, c, r, BLOCK, |j0, band| {
            let jrows = band.len() / r;
            for ib in (0..r).step_by(BLOCK) {
                let imax = (ib + BLOCK).min(r);
                for jr in 0..jrows {
                    let j = j0 + jr;
                    for i in ib..imax {
                        band[jr * r + i] = src[i * c + j];
                    }
                }
            }
        });
        Tensor::from_vec(&[c, r], out)
    }

    /// Elementwise `self - other`.
    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape(), other.shape());
        let data = self.data().iter().zip(other.data()).map(|(a, b)| a - b).collect();
        Tensor::from_vec(self.shape(), data)
    }

    /// Elementwise `self + other`.
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape(), other.shape());
        let data = self.data().iter().zip(other.data()).map(|(a, b)| a + b).collect();
        Tensor::from_vec(self.shape(), data)
    }

    /// Scale by a constant.
    pub fn scale(&self, s: f32) -> Tensor {
        Tensor::from_vec(self.shape(), self.data().iter().map(|a| a * s).collect())
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data().iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Mean squared error against another tensor of the same shape.
    pub fn mse(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape(), other.shape());
        let n = self.len().max(1) as f64;
        self.data()
            .iter()
            .zip(other.data())
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            / n
    }

    /// Largest absolute element.
    pub fn abs_max(&self) -> f32 {
        self.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Dot product of two equal-length slices (helper for kmeans/svd).
    pub fn dot(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        // Two partial sums help the autovectorizer; f64 accumulate for
        // stability on long channels.
        let mut s0 = 0.0f64;
        let mut s1 = 0.0f64;
        let mut i = 0;
        while i + 1 < a.len() {
            s0 += a[i] as f64 * b[i] as f64;
            s1 += a[i + 1] as f64 * b[i + 1] as f64;
            i += 2;
        }
        if i < a.len() {
            s0 += a[i] as f64 * b[i] as f64;
        }
        s0 + s1
    }

    /// Squared L2 distance between two slices.
    pub fn dist2(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let mut s = 0.0f64;
        for (&x, &y) in a.iter().zip(b) {
            let d = (x - y) as f64;
            s += d * d;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += a.at(i, kk) * b.at(kk, j);
                }
                *out.at_mut(i, j) = s;
            }
        }
        out
    }

    #[test]
    fn matmul_small_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![1., 1., 1., 1.]);
        assert_eq!(a.matmul(&b).data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_matches_naive_random_shapes() {
        prop::check(
            "blocked matmul == naive",
            11,
            16,
            |r| {
                let (m, k, n) = (1 + r.below(90), 1 + r.below(90), 1 + r.below(90));
                let a = Tensor::randn(&[m, k], r);
                let b = Tensor::randn(&[k, n], r);
                (a, b)
            },
            |(a, b)| prop::assert_close(a.matmul(b).data(), naive_matmul(a, b).data(), 1e-3, 1e-3),
        );
    }

    #[test]
    fn matmul_transpose_bitwise_parity_across_threads() {
        let mut r = Rng::new(14);
        // Ragged shapes on purpose (bands must handle partial chunks), and
        // large enough to clear the serial-fallback thresholds so the
        // parallel paths actually run.
        let a = Tensor::randn(&[260, 190], &mut r);
        let b = Tensor::randn(&[190, 170], &mut r);
        let t = Tensor::randn(&[430, 310], &mut r);
        assert!(260 * 190 * 170 >= MIN_PARALLEL_MACS_SPAWN);
        assert!(430 * 310 >= MIN_PARALLEL_ELEMS_SPAWN);
        // to_bits: derived f32 PartialEq is not bitwise (0.0 == -0.0).
        let bits = |x: &Tensor| x.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        let base_mm = bits(&a.matmul_with(&b, ExecConfig::serial()));
        let base_t = bits(&t.transpose_with(ExecConfig::serial()));
        for threads in [2, 4, 8] {
            let cfg = ExecConfig::with_threads(threads);
            assert_eq!(bits(&a.matmul_with(&b, cfg)), base_mm, "matmul, {threads} threads");
            assert_eq!(bits(&t.transpose_with(cfg)), base_t, "transpose, {threads} threads");
        }
    }

    #[test]
    fn packed_and_blocked_kernels_bitwise_identical() {
        use super::gemm::{self, GemmKernel};
        let mut r = Rng::new(15);
        let a = Tensor::randn(&[70, 45], &mut r);
        let b = Tensor::randn(&[45, 33], &mut r);
        let t = Tensor::randn(&[70, 21], &mut r);
        let bits = |x: &Tensor| x.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        gemm::set_kernel(GemmKernel::Blocked);
        let mm_blocked = bits(&a.matmul(&b));
        let tm_blocked = bits(&a.t_matmul(&t));
        gemm::set_kernel(GemmKernel::Packed);
        let mm_packed = bits(&a.matmul(&b));
        let tm_packed = bits(&a.t_matmul(&t));
        assert_eq!(mm_packed, mm_blocked, "matmul kernels disagree");
        assert_eq!(tm_packed, tm_blocked, "t_matmul kernels disagree");
    }

    #[test]
    fn matmul_add_assign_matches_add_of_matmul_bitwise() {
        let mut r = Rng::new(16);
        // Above the spawn serial-fallback threshold so the banded parallel
        // accumulate path actually runs.
        let a = Tensor::randn(&[260, 190], &mut r);
        let b = Tensor::randn(&[190, 170], &mut r);
        let base = Tensor::randn(&[260, 170], &mut r);
        let want = base.add(&a.matmul_with(&b, ExecConfig::serial()));
        let bits = |x: &Tensor| x.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        for threads in [1, 2, 4, 8] {
            let mut out = base.clone();
            a.matmul_add_assign_with(&b, &mut out, ExecConfig::with_threads(threads));
            assert_eq!(bits(&out), bits(&want), "{threads} threads");
        }
    }

    #[test]
    fn transpose_involution() {
        let mut r = Rng::new(12);
        let t = Tensor::randn(&[17, 31], &mut r);
        assert_eq!(t.transpose().transpose(), t);
    }

    #[test]
    fn t_matmul_matches_explicit() {
        let mut r = Rng::new(13);
        let a = Tensor::randn(&[20, 15], &mut r);
        let b = Tensor::randn(&[20, 10], &mut r);
        let got = a.t_matmul(&b);
        let want = a.transpose().matmul(&b);
        prop::assert_close(got.data(), want.data(), 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn mse_and_norms() {
        let a = Tensor::from_vec(&[1, 2], vec![3., 4.]);
        let b = Tensor::zeros(&[1, 2]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-9);
        assert!((a.mse(&b) - 12.5).abs() < 1e-9);
        assert_eq!(a.abs_max(), 4.0);
    }

    #[test]
    fn add_sub_scale() {
        let a = Tensor::from_vec(&[2], vec![1., 2.]);
        let b = Tensor::from_vec(&[2], vec![3., 5.]);
        assert_eq!(a.add(&b).data(), &[4., 7.]);
        assert_eq!(b.sub(&a).data(), &[2., 3.]);
        assert_eq!(a.scale(2.0).data(), &[2., 4.]);
    }

    #[test]
    fn dot_dist2() {
        assert_eq!(Tensor::dot(&[1., 2., 3.], &[4., 5., 6.]), 32.0);
        assert_eq!(Tensor::dist2(&[0., 0.], &[3., 4.]), 25.0);
    }
}
