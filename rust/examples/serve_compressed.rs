//! Compressed-domain serving demo — forward passes straight from `.swsc`
//! factors, no reconstruction, no artifacts required.
//!
//! Compresses a freshly initialized model's Q/K projectors, round-trips
//! the container through the on-disk format, then serves concurrent
//! linear requests through [`EvalService`] in both [`InferMode`]s:
//! `compressed` (bucket-sum/gather + low-rank GEMMs from the raw factors)
//! vs `reconstructed` (dense weights materialized at load — the old
//! route, kept as the oracle/baseline). Prints latency, throughput, the
//! compressed/dense storage ratio, and the flop-model speedup.
//!
//! Unlike `examples/serve_eval.rs` this needs no `make artifacts`: the
//! PJRT engine is only constructed lazily for eval requests, which this
//! demo never sends.

use std::sync::Arc;
use swsc::compress::{CompressionPlan, ProjectorSet};
use swsc::coordinator::{compress_model, EvalService, LinearRequest, ServiceConfig};
use swsc::exec::ExecConfig;
use swsc::infer::{CompressedLinear, CompressedModel, InferMode, Precision, QuantizedLinear};
use swsc::io::SwscFile;
use swsc::model::{init_params, ModelConfig};
use swsc::quant::QuantConfig;
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;
use swsc::obs::prof::Stats;

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::small();
    let ck = init_params(&cfg, 11);

    // Compress Q & K at 2 avg bits — the paper's Table I operating point.
    let plan = CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::QAndK, 2.0, 0.5, 11);
    println!("compressing {} matrices ({} avg bits target)...", plan.len(), 2.0);
    let outcome = compress_model(&ck, &plan, 8, None)?;

    // Round-trip the container through the on-disk format.
    let file = SwscFile::from_bytes(&outcome.file.to_bytes())?;
    let dense_bytes: usize = file
        .compressed
        .values()
        .map(|c| c.shape.0 * c.shape.1 * 2) // fp16 dense baseline
        .sum();
    println!(
        "container: {} compressed matrices, {} payload bytes (dense fp16 would be {}, {:.1}x)",
        file.compressed.len(),
        file.compressed_payload_bytes(),
        dense_bytes,
        dense_bytes as f64 / file.compressed_payload_bytes().max(1) as f64,
    );
    if let Some((name, c)) = file.compressed.iter().next() {
        let lin = CompressedLinear::from_matrix(c);
        println!(
            "flop model for {name} at b = {}: dense {} MACs vs compressed {} ({:.1}x)",
            cfg.d_model,
            lin.dense_macs(cfg.d_model),
            lin.compressed_macs(cfg.d_model),
            lin.dense_macs(cfg.d_model) as f64 / lin.compressed_macs(cfg.d_model) as f64,
        );
    }

    let names: Vec<String> = file.compressed.keys().cloned().collect();
    let clients = 4;
    let per_client = 32;
    let batch_rows = 16;

    for mode in [InferMode::Compressed, InferMode::Reconstructed] {
        // Direct-model sanity check before going through the service.
        let model = CompressedModel::from_file(&file, mode);
        let probe = Tensor::randn(&[2, cfg.d_model], &mut Rng::new(1));
        let y = model.apply(&names[0], &probe)?;
        anyhow::ensure!(y.shape() == [2, cfg.d_model], "unexpected output shape");

        let service = Arc::new(EvalService::start_with_swsc(
            None, // no artifacts: linear-only serving
            cfg.clone(),
            &file,
            ServiceConfig { infer_mode: mode, queue_capacity: 64, ..Default::default() },
        )?);

        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for cl in 0..clients {
            let service = service.clone();
            let names = names.clone();
            let d = cfg.d_model;
            handles.push(std::thread::spawn(move || -> anyhow::Result<Stats> {
                let mut rng = Rng::new(100 + cl as u64);
                let mut lat = Stats::new();
                for i in 0..per_client {
                    let name = names[(cl + i) % names.len()].clone();
                    let x = Tensor::randn(&[batch_rows, d], &mut rng);
                    let t = std::time::Instant::now();
                    let resp = service.linear_blocking(LinearRequest::new(name, x))?;
                    lat.push(t.elapsed().as_secs_f64());
                    anyhow::ensure!(resp.y.shape() == [batch_rows, d]);
                }
                Ok(lat)
            }));
        }
        let mut mean_ms = 0.0;
        for h in handles {
            let lat = h.join().unwrap()?;
            mean_ms += lat.mean() * 1e3 / clients as f64;
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = clients * per_client;
        println!(
            "\nmode {mode:?}: {total} linear requests ({batch_rows}-row batches) in {wall:.3}s \
             -> {:.0} req/s, mean latency {mean_ms:.3} ms",
            total as f64 / wall
        );
        println!("batcher metrics:\n{}", service.metrics.render());
        if let Ok(s) = Arc::try_unwrap(service) {
            s.shutdown();
        }
    }

    // Double compression: grouped-int8 factors + bit-packed labels, served
    // through the fused dequantize-in-register kernel (no dense f32
    // intermediate). Round-trip the version-2 container, then compare
    // `Precision::Int8` against the f32 oracle on the same factors.
    let mut qfile = SwscFile::new();
    for (name, c) in &file.compressed {
        qfile.quantized.insert(name.clone(), c.quantize(&QuantConfig::default()));
    }
    let qfile = SwscFile::from_bytes(&qfile.to_bytes())?;
    let (q_bytes, f_bytes) = (qfile.to_bytes().len(), file.to_bytes().len());
    println!(
        "\nquantized container: {q_bytes} B vs {f_bytes} B f32-factor ({:.2}x payload)",
        q_bytes as f64 / f_bytes.max(1) as f64,
    );
    if let Some((name, q)) = qfile.quantized.iter().next() {
        let exec = ExecConfig::serial();
        let qp = QuantizedLinear::from_matrix(q).apply_panel_bytes(exec);
        let fp = CompressedLinear::from_matrix(&file.compressed[name]).apply_panel_bytes(exec);
        println!("panel cache for {name}: {qp} B int8 vs {fp} B f32 ({:.2}x)", qp as f64 / fp as f64);
    }

    let int8 = CompressedModel::from_file_with(&qfile, InferMode::Compressed, Precision::Int8);
    let oracle = CompressedModel::from_file_with(&qfile, InferMode::Compressed, Precision::F32);
    let probe = Tensor::randn(&[batch_rows, cfg.d_model], &mut Rng::new(2));
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for name in &names {
        let (yq, yf) = (int8.apply(name, &probe)?, oracle.apply(name, &probe)?);
        for (a, b) in yq.data().iter().zip(yf.data()) {
            num += f64::from(a - b).powi(2);
            den += f64::from(*b).powi(2);
        }
    }
    let rel = (num / den.max(1e-30)).sqrt();
    println!("int8 vs f32 relative error across {} projectors: {rel:.2e}", names.len());
    anyhow::ensure!(rel < 0.05, "quantized serving drifted from the f32 oracle: {rel:.2e}");

    // Serve the quantized model through the service layer (Arc-shared
    // int8 panels) and make sure throughput survives the trip.
    let service = Arc::new(EvalService::start_with_swsc(
        None,
        cfg.clone(),
        &qfile,
        ServiceConfig {
            infer_mode: InferMode::Compressed,
            precision: Precision::Int8,
            queue_capacity: 64,
            ..Default::default()
        },
    )?);
    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(7);
    let reqs = 64usize;
    for i in 0..reqs {
        let name = names[i % names.len()].clone();
        let x = Tensor::randn(&[batch_rows, cfg.d_model], &mut rng);
        let resp = service.linear_blocking(LinearRequest::new(name, x))?;
        anyhow::ensure!(resp.y.shape() == [batch_rows, cfg.d_model]);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("precision Int8: {reqs} linear requests in {wall:.3}s -> {:.0} req/s", reqs as f64 / wall);
    if let Ok(s) = Arc::try_unwrap(service) {
        s.shutdown();
    }

    println!("note: perplexity eval still needs `make artifacts` (fwd_eval takes dense params)");
    Ok(())
}
