//! Compressed-domain forward serving demo — the whole transformer stack
//! served from a `.swsc` container with continuous batching. No
//! artifacts required (nothing here touches PJRT); CI runs this as a
//! smoke test.
//!
//! What it shows:
//!
//! 1. A [`CompressedForward`] built from a tiny-config `.swsc` container
//!    and registered behind a [`BatchServer`].
//! 2. The seeded forward loadgen replaying the identical mixed-length
//!    token stream through a continuous-batched server (requests join
//!    and leave the in-flight batch at layer boundaries) and a
//!    flush-the-batch server (the scheduling oracle).
//! 3. The bitwise contract: responses under either scheduler equal the
//!    solo `CompressedForward::forward` logits bit for bit.
//! 4. The `EvalService` forward surface: `forward_blocking` with
//!    batching enabled vs disabled (both bitwise equal to solo), and the
//!    explicit error when the container doesn't cover the full model.
//! 5. Compressed-domain perplexity: `eval::perplexity_swsc_compressed`
//!    scores a token stream with no PJRT engine and no reconstruction.

use std::sync::Arc;
use swsc::bench::loadgen::{run_forward_loadgen, ForwardLoadgenConfig};
use swsc::compress::{compress_matrix, SwscConfig};
use swsc::coordinator::{EvalService, ServiceConfig};
use swsc::infer::{CompressedForward, CompressedModel, InferMode};
use swsc::io::SwscFile;
use swsc::model::{init_params, param_specs, ModelConfig};
use swsc::serve::{
    BatchConfig, BatchServer, Batching, ForwardRequest, ForwardScheduling, ModelRegistry,
    DEFAULT_MODEL,
};
use swsc::text::Dataset;
use swsc::util::rng::Rng;

/// A tiny-config `.swsc` container covering every model parameter:
/// 2-D weights wide enough to cluster are SWSC-compressed, the rest
/// (embeddings aside, biases, layernorm gains) ride along dense.
fn demo_file(cfg: &ModelConfig, seed: u64) -> SwscFile {
    let ck = init_params(cfg, seed);
    let mut file = SwscFile::new();
    for spec in param_specs(cfg) {
        let t = ck.get(&spec.name).unwrap().clone();
        if spec.shape.len() == 2 && spec.shape[1] >= 16 {
            file.compressed.insert(spec.name.clone(), compress_matrix(&t, &SwscConfig::new(8, 2)));
        } else {
            file.dense.insert(spec.name.clone(), t);
        }
    }
    file
}

fn main() -> anyhow::Result<()> {
    // 1. One tiny model, compressed, behind a forward-serving registry.
    let cfg = ModelConfig::tiny();
    println!(
        "compressing tiny model (vocab {}, d_model {}, {} layers) into a .swsc container...",
        cfg.vocab, cfg.d_model, cfg.n_layers
    );
    let file = demo_file(&cfg, 17);
    let model = Arc::new(CompressedModel::from_file(&file, InferMode::Compressed));
    let fwd = Arc::new(CompressedForward::new(model, cfg.clone())?);
    let start_server = |scheduling: ForwardScheduling| {
        let reg = ModelRegistry::new();
        reg.insert_forward(DEFAULT_MODEL, fwd.clone());
        BatchServer::start(
            Arc::new(reg),
            BatchConfig::default().with_forward_scheduling(scheduling),
        )
    };

    // 2. The same seeded mixed-length stream, continuous vs flush. Window
    // lengths are drawn uniformly from 1..=seq, the convoy-prone shape:
    // under flush scheduling every short request waits out the longest
    // member of its batch; under continuous scheduling it exits at its
    // own final layer boundary while new arrivals join at layer 0.
    let lg = ForwardLoadgenConfig {
        seed: 0xF0F7,
        requests: 64,
        max_tokens: cfg.seq,
        mixed: true,
        rate_rps: 0.0, // saturation
        models: vec![DEFAULT_MODEL.to_string()],
        deadline: None,
    };
    let replay = |scheduling: ForwardScheduling| -> anyhow::Result<_> {
        let server = start_server(scheduling);
        let rep = run_forward_loadgen(&server, &lg)?;
        server.shutdown();
        Ok(rep)
    };
    let cont = replay(ForwardScheduling::Continuous)?;
    let flush = replay(ForwardScheduling::Flush)?;
    println!("\ncontinuous: {}", cont.render());
    println!("flush:      {}", flush.render());
    println!(
        "p95 latency: continuous {:.0} µs vs flush {:.0} µs ({:.2}x); mean {:.1} stacked \
         rows/layer-step over {} steps",
        cont.p95_us,
        flush.p95_us,
        flush.p95_us / cont.p95_us.max(1e-12),
        cont.batch_mean,
        cont.batches,
    );
    anyhow::ensure!(cont.errors == 0 && flush.errors == 0, "loadgen saw error responses");

    // 3. Bitwise parity: under either scheduler, served logits equal the
    // solo forward bit for bit — layer-boundary re-forming is pure
    // scheduling, never arithmetic.
    let mut rng = Rng::new(42);
    let windows: Vec<Vec<u32>> = (0..6)
        .map(|_| {
            let t = 1 + rng.below(cfg.seq);
            (0..t).map(|_| rng.below(cfg.vocab) as u32).collect()
        })
        .collect();
    for scheduling in [ForwardScheduling::Continuous, ForwardScheduling::Flush] {
        let server = start_server(scheduling);
        for tokens in &windows {
            let got = server
                .submit_forward_blocking(DEFAULT_MODEL, ForwardRequest::new(tokens.clone()))?;
            let want = fwd.forward(tokens)?;
            anyhow::ensure!(
                got.logits == want,
                "{scheduling:?} response diverged from solo forward ({} tokens)",
                tokens.len()
            );
        }
        server.shutdown();
    }
    println!("\nbitwise parity vs solo forward: OK ({} windows x 2 schedulers)", windows.len());

    // 4. EvalService forward surface: batching Enabled routes through the
    // continuous coalescer, Disabled serves inline — both bitwise equal
    // to the solo oracle.
    for (label, batching) in [("enabled", Batching::default()), ("disabled", Batching::Disabled)] {
        let svc_cfg = ServiceConfig { batching, ..Default::default() };
        let service = EvalService::start_with_swsc(None, cfg.clone(), &file, svc_cfg)?;
        anyhow::ensure!(service.has_forward(), "full container must enable forward serving");
        let resp = service.forward_blocking(ForwardRequest::new(windows[0].clone()))?;
        let want = fwd.forward(&windows[0])?;
        anyhow::ensure!(
            resp.logits == want,
            "EvalService forward (batching {label}) diverged from solo"
        );
        service.shutdown();
    }
    println!("EvalService forward surface: OK (batching enabled + disabled, both bitwise)");

    // A container that misses parameters serves linears only; the
    // forward surface refuses with an explicit error instead of
    // panicking mid-request.
    let mut partial = SwscFile::new();
    let mut prng = Rng::new(5);
    partial.dense.insert(
        "lonely.weight".into(),
        swsc::tensor::Tensor::randn(&[cfg.d_model, cfg.d_model], &mut prng),
    );
    let partial_svc = EvalService::start_with_swsc(None, cfg.clone(), &partial, ServiceConfig::default())?;
    anyhow::ensure!(!partial_svc.has_forward(), "partial container must not enable forward");
    let err = partial_svc.forward_blocking(ForwardRequest::new(vec![1, 2, 3]));
    anyhow::ensure!(err.is_err(), "partial container must refuse forward requests");
    println!("partial container: forward refused with `{}`", err.unwrap_err());
    partial_svc.shutdown();

    // 5. Compressed-domain perplexity: the same chained forward scores a
    // token stream — no PJRT engine, no artifacts, no reconstruction.
    let len = cfg.batch * cfg.seq + 1;
    let ids: Vec<i32> = (0..len).map(|i| (i * 7 % cfg.vocab) as i32).collect();
    let data = Dataset::from_ids(ids, cfg.batch, cfg.seq);
    let result = swsc::eval::perplexity_swsc_compressed(
        &file,
        &cfg,
        InferMode::Compressed,
        &data,
        swsc::exec::global(),
    )?;
    println!(
        "\ncompressed-domain perplexity: {:.2} over {} tokens ({} batches) — fresh init, \
         so ~= vocab {}",
        result.perplexity, result.tokens, result.batches, cfg.vocab
    );
    anyhow::ensure!(result.perplexity.is_finite(), "perplexity must be finite");
    Ok(())
}
