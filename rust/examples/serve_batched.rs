//! Batched serving demo — micro-batch coalescing, multi-model registry,
//! and admission backpressure over the compressed-domain engine. No
//! artifacts required (nothing here touches PJRT); CI runs this as a
//! smoke test.
//!
//! What it shows:
//!
//! 1. A [`ModelRegistry`] holding two independently compressed models
//!    behind one [`BatchServer`].
//! 2. The seeded open-loop loadgen replaying the identical request
//!    stream through a coalescing server and a solo server
//!    (`BatchConfig::solo()`), with throughput and p50/p95/p99 latency
//!    from the fixed-size metric histograms.
//! 3. The bitwise contract: batched responses equal direct
//!    `CompressedModel::apply` results bit for bit.
//! 4. Explicit `Overloaded` / `ShuttingDown` admission rejections.
//! 5. The `EvalService` integration: `ServiceConfig::batching` routes
//!    `submit_linear` through the coalescer by default.
//! 6. Observability (PR 9): request-scoped tracing enabled explicitly,
//!    Chrome trace export validated, Prometheus/JSON exporters
//!    line-format-checked with per-model labels. (CI also runs this
//!    whole smoke with `SWSC_TRACE=1`, so every server above traces too
//!    — bitwise invisibly; step 3 is the proof.)

use std::sync::Arc;
use swsc::bench::loadgen::{run_loadgen, LoadgenConfig, LoadgenReport};
use swsc::compress::{compress_matrix, SwscConfig};
use swsc::coordinator::{EvalService, ServiceConfig};
use swsc::infer::InferMode;
use swsc::io::SwscFile;
use swsc::model::ModelConfig;
use swsc::obs::TraceConfig;
use swsc::serve::{
    AdmissionError, BatchConfig, BatchServer, LinearRequest, ModelRegistry, ServerOptions,
};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

const D: usize = 128;

fn demo_file(seed: u64) -> SwscFile {
    let mut rng = Rng::new(seed);
    let mut file = SwscFile::new();
    for name in ["attn.wq", "attn.wk"] {
        let w = Tensor::randn(&[D, D], &mut rng);
        file.compressed.insert(name.into(), compress_matrix(&w, &SwscConfig::new(8, 4)));
    }
    file.dense.insert("attn.wv".into(), Tensor::randn(&[D, D], &mut rng));
    file
}

fn main() -> anyhow::Result<()> {
    // 1. Two models, one registry, one server.
    println!("compressing two demo models ({D}x{D} Q/K at k=8, r=4)...");
    let files = [("prod", demo_file(21)), ("canary", demo_file(22))];
    let registry = ModelRegistry::new();
    for (name, file) in &files {
        registry.insert_file(name, file, InferMode::Compressed);
    }
    let registry = Arc::new(registry);
    let mut targets = Vec::new();
    for (model, _) in &files {
        for weight in ["attn.wq", "attn.wk", "attn.wv"] {
            targets.push((model.to_string(), weight.to_string()));
        }
    }

    // 2. The same seeded stream, coalesced vs solo.
    let lg = LoadgenConfig {
        seed: 7,
        requests: 256,
        rows_per_request: 8,
        ragged: true,
        rate_rps: 0.0, // saturation
        targets: targets.clone(),
        deadline: None,
    };
    let run = |cfg: BatchConfig| -> anyhow::Result<LoadgenReport> {
        let server = BatchServer::start(registry.clone(), cfg);
        let rep = run_loadgen(&server, &lg)?;
        server.shutdown();
        Ok(rep)
    };
    let batched = run(BatchConfig::default())?;
    let solo = run(BatchConfig::solo())?;
    println!("\nbatched: {}", batched.render());
    println!("solo:    {}", solo.render());
    println!(
        "coalescing speedup: {:.2}x throughput (mean batch {:.1} rows)",
        solo.wall_seconds / batched.wall_seconds.max(1e-12),
        batched.batch_mean
    );
    anyhow::ensure!(batched.errors == 0 && solo.errors == 0, "loadgen saw error responses");

    // A rate-limited open-loop replay (Poisson arrivals) for the latency
    // view — arrivals paced by the stream clock, not by completions.
    let paced_server = BatchServer::start(registry.clone(), BatchConfig::default());
    let paced = run_loadgen(
        &paced_server,
        &LoadgenConfig { requests: 64, rate_rps: 2000.0, ..lg.clone() },
    )?;
    println!("paced @2000 req/s: {}", paced.render());
    paced_server.shutdown();

    // 3. Bitwise parity: batched responses == direct apply, bit for bit.
    let server = BatchServer::start(registry.clone(), BatchConfig::default());
    let mut rng = Rng::new(42);
    for (model_name, weight) in &targets {
        let model = registry.get(model_name).unwrap();
        let (m, _) = model.shape(weight).unwrap();
        let x = Tensor::randn(&[3, m], &mut rng);
        let got = server
            .submit_blocking(model_name, LinearRequest::new(weight.clone(), x.clone()))?;
        let want = model.apply(weight, &x)?;
        anyhow::ensure!(
            got.y == want,
            "batched response diverged from direct apply for {model_name}/{weight}"
        );
    }
    println!("\nbitwise parity vs direct apply: OK ({} (model, weight) pairs)", targets.len());

    // 4. Backpressure: a tiny queue sheds load explicitly while the
    // coalescer grinds a deliberately large request.
    let tiny = BatchServer::start_with(
        registry.clone(),
        BatchConfig::solo(),
        2,
        Arc::new(swsc::coordinator::Metrics::new()),
    );
    let big = Tensor::randn(&[16384, D], &mut rng);
    let slow = tiny
        .submit("prod", LinearRequest::new("attn.wq", big))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut overloaded = 0;
    let mut accepted = Vec::new();
    for _ in 0..4 {
        match tiny.try_submit("prod", LinearRequest::new("attn.wq", Tensor::zeros(&[1, D]))) {
            Ok(rx) => accepted.push(rx),
            Err(AdmissionError::Overloaded) => overloaded += 1,
            Err(e) => anyhow::bail!("unexpected admission error: {e}"),
        }
    }
    println!(
        "backpressure: queue capacity {}, {} accepted, {} rejected Overloaded",
        tiny.queue().capacity(),
        accepted.len(),
        overloaded
    );
    anyhow::ensure!(slow.recv()?.is_ok(), "big request failed");
    for rx in accepted {
        anyhow::ensure!(rx.recv()?.is_ok(), "accepted request failed");
    }
    tiny.begin_shutdown();
    let refused = tiny.try_submit("prod", LinearRequest::new("attn.wq", Tensor::zeros(&[1, D])));
    anyhow::ensure!(
        refused.err() == Some(AdmissionError::ShuttingDown),
        "post-shutdown admission must be rejected"
    );
    println!("shutdown: new admissions rejected with ShuttingDown, admitted work served");
    tiny.shutdown();

    // 5. EvalService integration: submit_linear routes through the
    // coalescer by default (ServiceConfig::batching), bitwise identical
    // to the old inline path.
    let cfg = ModelConfig::tiny();
    let service = EvalService::start_with_swsc(
        None,
        cfg,
        &files[0].1,
        ServiceConfig::default(), // batching: Enabled
    )?;
    let x = Tensor::randn(&[4, D], &mut rng);
    let resp = service.linear_blocking(LinearRequest::new("attn.wq", x.clone()))?;
    let want = registry.get("prod").unwrap().apply("attn.wq", &x)?;
    anyhow::ensure!(resp.y == want, "EvalService batched path diverged");
    println!("\nEvalService (batching enabled) metrics:\n{}", service.metrics.render());
    service.shutdown();

    // 6. Observability: a traced replay, then the three export surfaces.
    let traced = BatchServer::start_with_opts(
        registry.clone(),
        BatchConfig::default(),
        ServerOptions { trace: Some(TraceConfig::default()), ..ServerOptions::default() },
    );
    let rep = run_loadgen(&traced, &LoadgenConfig { requests: 32, ..lg.clone() })?;
    anyhow::ensure!(rep.errors == 0, "traced replay saw error responses");
    let chrome = traced.dump_trace().expect("tracing enabled above");
    anyhow::ensure!(
        chrome.starts_with('[') && chrome.trim_end().ends_with(']'),
        "chrome export must be a JSON array"
    );
    anyhow::ensure!(
        chrome.matches('{').count() == chrome.matches('}').count(),
        "chrome export braces must balance"
    );
    anyhow::ensure!(
        chrome.contains("\"queue_wait\"") && chrome.contains("\"group_apply\""),
        "expected span kinds missing from the trace"
    );
    let sink = traced.trace_sink().expect("tracing enabled above");
    println!(
        "\ntrace: {} records ({} dropped), chrome export {} bytes",
        sink.len(),
        sink.dropped(),
        chrome.len()
    );

    // Prometheus text format: every line is a comment or a sample, and
    // the per-model breakdowns carry `model="…"` labels.
    let prom = traced.metrics().render_prometheus();
    for line in prom.lines() {
        anyhow::ensure!(
            line.starts_with("# TYPE ") || line.starts_with("swsc_"),
            "prometheus line-format violation: {line}"
        );
    }
    anyhow::ensure!(prom.contains("model=\""), "per-model labels missing from prometheus export");
    anyhow::ensure!(
        prom.contains("swsc_serve_latency_seconds"),
        "latency family missing from prometheus export"
    );
    let js = traced.metrics().render_json();
    anyhow::ensure!(
        js.trim_start().starts_with('{') && js.matches('{').count() == js.matches('}').count(),
        "json snapshot must be brace-balanced"
    );
    anyhow::ensure!(js.contains("\"labeled_counters\""), "json snapshot missing labeled section");
    println!(
        "exporters: prometheus {} lines, json {} bytes — deterministic, sorted",
        prom.lines().count(),
        js.len()
    );
    traced.shutdown();

    println!("note: perplexity eval still needs `make artifacts` (fwd_eval takes dense params)");
    Ok(())
}
